//! Native full-model engine: ViT fine-tuning reconstructed in pure rust
//! from the manifest's `param_spec`, with no HLO execution anywhere on
//! the path.
//!
//! [`ModelPlan::from_entry`] parses the flat parameter layout back into
//! the ViT-tiny architecture the AOT pipeline lowered (patch embed →
//! CLS/pos → transformer blocks → final norm → head) and refuses any
//! tensor name it does not recognize — a wrong-model manifest fails
//! loudly instead of training garbage.  [`NativeModelEngine`] then
//! chains the existing `wasi::layer` engines (DenseLayer for dense
//! linears, WasiLayer for factored ones, ASI state threaded through the
//! flat state vector) into a full forward/backward with softmax
//! cross-entropy, global-norm gradient clipping, decoupled weight decay
//! on the matrix weights, SGD, and the per-step WSI refresh — the same
//! `(params, state, x, y, lr) -> (loss, acc)` contract as the AOT train
//! step.
//!
//! **Documented substitution (DESIGN.md §4):** inside each block the
//! softmax attention matrix is replaced by the fixed doubly-stochastic
//! mixing `(I + 11ᵀ/T)/2` applied to the value path
//! (`qkv → v → mix → proj`) — an attention-shaped dense stack.  The
//! trainable linears, their shapes, the residual structure, the
//! activation-memory profile, and the patch→CLS information flow are
//! identical to the lowered model; only the mixing weights (which the
//! softmax computes from q/k and which carry no trainable parameters of
//! their own) are fixed, so the q/k columns of `qkv.w` receive zero
//! gradient.  Fine-tuning dynamics (loss descent, factored updates, ASI
//! compression) are preserved; absolute accuracies are not comparable
//! across engines.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::linalg::matrix::Mat;
use crate::linalg::tucker::Tensor;
use crate::runtime::{ModelEntry, StepOutput, TensorSpec};
use crate::wasi::asi::AsiCompressor;
use crate::wasi::layer::{DenseLayer, WasiLayer};
use crate::wasi::wsi::WsiFactors;

use super::{EngineKind, InferEngine, TrainEngine};

/// Mirrors the AOT pipeline's training hyperparameters
/// (`python/compile/train.py`): global-norm clip and decoupled weight
/// decay on `.w`/`.l`/`.r` tensors only.
const GRAD_CLIP: f32 = 2.0;
const WEIGHT_DECAY: f32 = 1e-4;
const LN_EPS: f32 = 1e-6;

// ---------------------------------------------------------------------------
// Plan: param_spec -> architecture
// ---------------------------------------------------------------------------

/// How one linear layer is parameterized in the flat vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LinearForm {
    /// `{prefix}.w` (O, I)
    Dense,
    /// `{prefix}.l` (O, K) + `{prefix}.r` (K, I)
    Factored { k: usize },
}

/// One linear layer recovered from the spec.
#[derive(Debug, Clone)]
pub struct LinearPlan {
    pub name: String,
    pub form: LinearForm,
    pub out_dim: usize,
    pub in_dim: usize,
}

/// The ViT architecture reconstructed from a manifest entry's
/// `param_spec` (see `python/compile/model.py::init_vit` for the
/// authoritative naming).
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub dim: usize,
    pub depth: usize,
    pub tokens: usize,
    pub patch: usize,
    pub image: usize,
    pub patch_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    /// Per block: qkv, proj, fc1, fc2.
    pub blocks: Vec<[LinearPlan; 4]>,
    specs: BTreeMap<String, TensorSpec>,
}

fn isqrt(n: usize) -> Option<usize> {
    let r = (n as f64).sqrt().round() as usize;
    (r * r == n).then_some(r)
}

impl ModelPlan {
    /// Parse a `param_spec` back into the ViT layer graph.  Every tensor
    /// name must be accounted for; unknown names (SwinLite stages,
    /// TinyDec token embeddings, corrupt specs) are refused.
    pub fn from_entry(entry: &ModelEntry) -> Result<ModelPlan> {
        if entry.param_spec.is_empty() {
            bail!(
                "model {}: manifest entry has no param_spec; the native \
                 engine cannot reconstruct the layer graph",
                entry.name
            );
        }
        let mut specs = BTreeMap::new();
        for t in &entry.param_spec {
            if t.offset + t.numel() > entry.params_len {
                bail!(
                    "model {}: tensor {} [{:?} @ {}] overruns params_len {}",
                    entry.name, t.name, t.shape, t.offset, entry.params_len
                );
            }
            if specs.insert(t.name.clone(), t.clone()).is_some() {
                bail!("model {}: duplicate param_spec tensor {}", entry.name, t.name);
            }
        }
        let get = |name: &str| -> Result<&TensorSpec> {
            specs.get(name).ok_or_else(|| {
                anyhow!("model {}: param_spec is missing tensor {name:?}", entry.name)
            })
        };

        // Fixed scaffolding tensors.
        let embed = get("embed.w")?;
        if embed.shape.len() != 2 {
            bail!("embed.w must be (D, patch_dim), got {:?}", embed.shape);
        }
        let (dim, patch_dim) = (embed.shape[0], embed.shape[1]);
        let pos = get("pos")?;
        if pos.shape.len() != 3 || pos.shape[0] != 1 || pos.shape[2] != dim {
            bail!("pos must be (1, tokens, {dim}), got {:?}", pos.shape);
        }
        let tokens = pos.shape[1];
        if tokens < 2 {
            bail!("pos token count {tokens} too small for CLS + patches");
        }
        let cls = get("cls")?;
        if cls.shape != [1, 1, dim] {
            bail!("cls must be (1, 1, {dim}), got {:?}", cls.shape);
        }
        let head = get("head.w")?;
        if head.shape.len() != 2 || head.shape[1] != dim {
            bail!("head.w must be (classes, {dim}), got {:?}", head.shape);
        }
        let classes = head.shape[0];
        if classes != entry.classes {
            bail!("head.w rows {} != manifest classes {}", classes, entry.classes);
        }
        let patch = isqrt(patch_dim / 3)
            .filter(|p| p * p * 3 == patch_dim)
            .ok_or_else(|| anyhow!("patch_dim {patch_dim} is not 3·p²"))?;
        let grid = isqrt(tokens - 1)
            .ok_or_else(|| anyhow!("tokens {tokens} is not g²+1"))?;
        let image = grid * patch;
        if image * image * 3 != entry.input_dim {
            bail!(
                "reconstructed image {image}x{image}x3 != manifest input_dim {}",
                entry.input_dim
            );
        }

        // Blocks: contiguous indices, each with the full layer set.
        let mut depth = 0;
        for name in specs.keys() {
            if let Some(rest) = name.strip_prefix("blocks.") {
                let idx: usize = rest
                    .split('.')
                    .next()
                    .unwrap_or("")
                    .parse()
                    .map_err(|_| anyhow!("bad block tensor name {name:?}"))?;
                depth = depth.max(idx + 1);
            }
        }
        if depth == 0 {
            bail!("model {}: param_spec has no blocks.* tensors", entry.name);
        }

        let linear_plan = |prefix: &str, o: usize, i: usize| -> Result<LinearPlan> {
            let b = get(&format!("{prefix}.b"))?;
            if b.shape != [o] {
                bail!("{prefix}.b must be ({o},), got {:?}", b.shape);
            }
            if let Some(w) = specs.get(&format!("{prefix}.w")) {
                if w.shape != [o, i] {
                    bail!("{prefix}.w must be ({o}, {i}), got {:?}", w.shape);
                }
                return Ok(LinearPlan {
                    name: prefix.to_string(),
                    form: LinearForm::Dense,
                    out_dim: o,
                    in_dim: i,
                });
            }
            let l = get(&format!("{prefix}.l"))?;
            let r = get(&format!("{prefix}.r"))?;
            if l.shape.len() != 2 || r.shape.len() != 2 || l.shape[0] != o
                || r.shape[1] != i || l.shape[1] != r.shape[0]
            {
                bail!(
                    "{prefix}: factored shapes l {:?} / r {:?} inconsistent with ({o}, {i})",
                    l.shape, r.shape
                );
            }
            Ok(LinearPlan {
                name: prefix.to_string(),
                form: LinearForm::Factored { k: l.shape[1] },
                out_dim: o,
                in_dim: i,
            })
        };

        let mut hidden = 0;
        let mut blocks = Vec::with_capacity(depth);
        for b in 0..depth {
            let p = format!("blocks.{b}");
            for ln in ["ln1", "ln2"] {
                for gb in ["g", "b"] {
                    let t = get(&format!("{p}.{ln}.{gb}"))?;
                    if t.shape != [dim] {
                        bail!("{p}.{ln}.{gb} must be ({dim},), got {:?}", t.shape);
                    }
                }
            }
            let fc1 = {
                // hidden comes from the first block's fc1 output.
                let probe = specs
                    .get(&format!("{p}.mlp.fc1.w"))
                    .or_else(|| specs.get(&format!("{p}.mlp.fc1.l")))
                    .ok_or_else(|| anyhow!("{p}.mlp.fc1 has neither .w nor .l"))?;
                let h = probe.shape.first().copied().unwrap_or(0);
                if hidden == 0 {
                    hidden = h;
                }
                linear_plan(&format!("{p}.mlp.fc1"), hidden, dim)?
            };
            blocks.push([
                linear_plan(&format!("{p}.attn.qkv"), 3 * dim, dim)?,
                linear_plan(&format!("{p}.attn.proj"), dim, dim)?,
                fc1,
                linear_plan(&format!("{p}.mlp.fc2"), dim, hidden)?,
            ]);
        }
        for suffix in ["norm.g", "norm.b"] {
            let t = get(suffix)?;
            if t.shape != [dim] {
                bail!("{suffix} must be ({dim},), got {:?}", t.shape);
            }
        }
        let hb = get("head.b")?;
        if hb.shape != [classes] {
            bail!("head.b must be ({classes},), got {:?}", hb.shape);
        }
        let eb = get("embed.b")?;
        if eb.shape != [dim] {
            bail!("embed.b must be ({dim},), got {:?}", eb.shape);
        }

        // Grammar closure: the spec must contain exactly the tensors
        // the reconstructed plan accounts for — the expected-name set is
        // generated from the plan itself, so the grammar lives in one
        // place.  (Missing tensors already failed above via `get`.)
        let mut expected: std::collections::BTreeSet<String> = [
            "embed.w", "embed.b", "cls", "pos", "norm.g", "norm.b", "head.w", "head.b",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        for (bi, blist) in blocks.iter().enumerate() {
            for ln in ["ln1", "ln2"] {
                for gb in ["g", "b"] {
                    expected.insert(format!("blocks.{bi}.{ln}.{gb}"));
                }
            }
            for lp in blist {
                expected.insert(format!("{}.b", lp.name));
                match lp.form {
                    LinearForm::Dense => {
                        expected.insert(format!("{}.w", lp.name));
                    }
                    LinearForm::Factored { .. } => {
                        expected.insert(format!("{}.l", lp.name));
                        expected.insert(format!("{}.r", lp.name));
                    }
                }
            }
        }
        for name in specs.keys() {
            if !expected.contains(name) {
                bail!(
                    "model {}: param_spec tensor {name:?} is not part of the \
                     ViT layer grammar; the native engine refuses to guess \
                     (only vit_* variants are reconstructable)",
                    entry.name
                );
            }
        }

        Ok(ModelPlan {
            dim, depth, tokens, patch, image, patch_dim, hidden, classes,
            blocks,
            specs,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&TensorSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| anyhow!("no tensor {name:?} in plan"))
    }
}

// ---------------------------------------------------------------------------
// Layer building blocks
// ---------------------------------------------------------------------------

/// Per-token layer norm with saved normalization stats for backward.
struct LayerNormSlot {
    g: Vec<f32>,
    b: Vec<f32>,
    saved: Option<(Vec<f32>, Vec<f32>, Vec<usize>)>, // (xhat, inv_std, shape)
}

impl LayerNormSlot {
    fn new(d: usize) -> Self {
        LayerNormSlot { g: vec![1.0; d], b: vec![0.0; d], saved: None }
    }

    fn forward(&mut self, x: &Tensor) -> Tensor {
        let d = *x.shape.last().unwrap();
        let rows = x.numel() / d;
        let mut xhat = vec![0.0f32; x.numel()];
        let mut inv_std = vec![0.0f32; rows];
        let mut y = vec![0.0f32; x.numel()];
        for r in 0..rows {
            let xi = &x.data[r * d..(r + 1) * d];
            let mu = xi.iter().sum::<f32>() / d as f32;
            let var = xi.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            let is = 1.0 / (var + LN_EPS).sqrt();
            inv_std[r] = is;
            for c in 0..d {
                let h = (xi[c] - mu) * is;
                xhat[r * d + c] = h;
                y[r * d + c] = h * self.g[c] + self.b[c];
            }
        }
        self.saved = Some((xhat, inv_std, x.shape.clone()));
        Tensor::from_vec(&x.shape, y)
    }

    /// Returns (dx, dg, db).
    fn backward(&mut self, dy: &Tensor) -> (Tensor, Vec<f32>, Vec<f32>) {
        let (xhat, inv_std, shape) = self.saved.take().expect("ln forward before backward");
        let d = *shape.last().unwrap();
        let rows = dy.numel() / d;
        let mut dg = vec![0.0f32; d];
        let mut db = vec![0.0f32; d];
        let mut dx = vec![0.0f32; dy.numel()];
        for r in 0..rows {
            let dyr = &dy.data[r * d..(r + 1) * d];
            let xhr = &xhat[r * d..(r + 1) * d];
            let mut m1 = 0.0f32; // mean(dxhat)
            let mut m2 = 0.0f32; // mean(dxhat * xhat)
            for c in 0..d {
                let dxh = dyr[c] * self.g[c];
                m1 += dxh;
                m2 += dxh * xhr[c];
                dg[c] += dyr[c] * xhr[c];
                db[c] += dyr[c];
            }
            m1 /= d as f32;
            m2 /= d as f32;
            for c in 0..d {
                let dxh = dyr[c] * self.g[c];
                dx[r * d + c] = inv_std[r] * (dxh - m1 - xhr[c] * m2);
            }
        }
        (Tensor::from_vec(&shape, dx), dg, db)
    }
}

/// Dense or WASI-factored linear with bias, backed by the wasi::layer
/// engines.
enum LinearKind {
    Dense(DenseLayer),
    Wasi(WasiLayer),
}

struct LinearSlot {
    plan: LinearPlan,
    kind: LinearKind,
    bias: Vec<f32>,
}

impl LinearSlot {
    fn forward(&mut self, x: &Tensor) -> Tensor {
        let mut y = match &mut self.kind {
            LinearKind::Dense(d) => d.forward(x),
            LinearKind::Wasi(w) => w.forward(x),
        };
        let o = self.plan.out_dim;
        for chunk in y.data.chunks_mut(o) {
            for (v, b) in chunk.iter_mut().zip(&self.bias) {
                *v += b;
            }
        }
        y
    }

    /// Backward; writes this layer's weight/bias grads into the flat
    /// gradient vector and returns dx.
    fn backward(&mut self, dy: &Tensor, plan: &ModelPlan, grads: &mut [f32]) -> Result<Tensor> {
        let o = self.plan.out_dim;
        let bspec = plan.spec(&format!("{}.b", self.plan.name))?;
        {
            let db = &mut grads[bspec.offset..bspec.offset + o];
            for chunk in dy.data.chunks(o) {
                for (g, v) in db.iter_mut().zip(chunk) {
                    *g += v;
                }
            }
        }
        match &mut self.kind {
            LinearKind::Dense(d) => {
                let (dx, dw) = d.backward(dy);
                write_grad(grads, plan.spec(&format!("{}.w", self.plan.name))?, &dw.data);
                Ok(dx)
            }
            LinearKind::Wasi(w) => {
                let (dx, dl, dr) = w.backward(dy);
                write_grad(grads, plan.spec(&format!("{}.l", self.plan.name))?, &dl.data);
                write_grad(grads, plan.spec(&format!("{}.r", self.plan.name))?, &dr.data);
                Ok(dx)
            }
        }
    }
}

fn write_grad(grads: &mut [f32], spec: &TensorSpec, data: &[f32]) {
    grads[spec.offset..spec.offset + data.len()].copy_from_slice(data);
}

struct BlockSlots {
    ln1: LayerNormSlot,
    qkv: LinearSlot,
    proj: LinearSlot,
    ln2: LayerNormSlot,
    fc1: LinearSlot,
    fc2: LinearSlot,
    gelu_in: Option<Tensor>,
}

// ---------------------------------------------------------------------------
// Activation math
// ---------------------------------------------------------------------------

const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_A: f32 = 0.044_715;

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh())
}

fn gelu_grad(x: f32) -> f32 {
    let inner = GELU_C * (x + GELU_A * x * x * x);
    let t = inner.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

/// (B, image²·3) flat images -> (B, grid², patch²·3) patch tokens
/// (matches `model.py::patchify`'s reshape/transpose).
fn patchify(x: &[f32], b: usize, image: usize, patch: usize) -> Tensor {
    let grid = image / patch;
    let pd = patch * patch * 3;
    let mut out = vec![0.0f32; b * grid * grid * pd];
    for bi in 0..b {
        for gy in 0..grid {
            for py in 0..patch {
                for gx in 0..grid {
                    for px in 0..patch {
                        for c in 0..3 {
                            let src = bi * image * image * 3
                                + ((gy * patch + py) * image + gx * patch + px) * 3
                                + c;
                            let dst = ((bi * grid + gy) * grid + gx) * pd
                                + (py * patch + px) * 3
                                + c;
                            out[dst] = x[src];
                        }
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[b, grid * grid, pd], out)
}

/// The fixed token mixing standing in for softmax attention:
/// `out = ((I + 11ᵀ/T) / 2) · v` per batch element — half identity,
/// half uniform attention.  Doubly stochastic, parameter-free, and
/// symmetric (so backward applies the same operator).  This is what
/// routes patch information to the CLS head without executing softmax
/// attention (DESIGN.md §4 substitution).
fn uniform_mix(v: &mut [f32], b: usize, t: usize, d: usize) {
    let mut mean = vec![0.0f32; d];
    for bi in 0..b {
        mean.iter_mut().for_each(|m| *m = 0.0);
        let batch = &v[bi * t * d..(bi + 1) * t * d];
        for row in batch.chunks(d) {
            for (m, x) in mean.iter_mut().zip(row) {
                *m += x;
            }
        }
        for m in mean.iter_mut() {
            *m /= t as f32;
        }
        let batch = &mut v[bi * t * d..(bi + 1) * t * d];
        for row in batch.chunks_mut(d) {
            for (x, m) in row.iter_mut().zip(&mean) {
                *x = 0.5 * *x + 0.5 * m;
            }
        }
    }
}

fn log_softmax_rows(logits: &[f32], classes: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; logits.len()];
    for (row, chunk) in logits.chunks(classes).enumerate() {
        let m = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = chunk.iter().map(|v| (v - m).exp()).sum::<f32>().ln() + m;
        for (c, &v) in chunk.iter().enumerate() {
            out[row * classes + c] = v - lse;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// Pure-rust training engine for one ViT variant.
pub struct NativeModelEngine {
    entry: ModelEntry,
    plan: ModelPlan,
    flat_params: Vec<f32>,
    flat_state: Vec<f32>,
    embed: LinearSlot,
    cls: Vec<f32>,
    pos: Vec<f32>,
    blocks: Vec<BlockSlots>,
    norm: LayerNormSlot,
    head: LinearSlot,
}

fn seed_from(name: &str) -> u64 {
    // FNV-1a over the layer name: deterministic ASI init when the
    // manifest ships no state vector.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl NativeModelEngine {
    /// Build from a manifest entry, loading initial params/state from
    /// the artifact files.
    pub fn load(entry: &ModelEntry) -> Result<Self> {
        let params = entry.load_params()?;
        let state = entry.load_state()?;
        Self::from_flat(entry, params, state)
    }

    /// Build from explicit flat vectors (checkpoint restore, tests).
    pub fn from_flat(entry: &ModelEntry, params: Vec<f32>, state: Vec<f32>) -> Result<Self> {
        if params.len() != entry.params_len {
            bail!("params length {} != manifest {}", params.len(), entry.params_len);
        }
        if state.len() != entry.state_len {
            bail!("state length {} != manifest {}", state.len(), entry.state_len);
        }
        let plan = ModelPlan::from_entry(entry)?;
        let dims = [entry.batch, plan.tokens, 0usize]; // last dim set per layer

        let build_linear = |lp: &LinearPlan| -> Result<LinearSlot> {
            let kind = match lp.form {
                LinearForm::Dense => {
                    LinearKind::Dense(DenseLayer::new(Mat::zeros(lp.out_dim, lp.in_dim)))
                }
                LinearForm::Factored { k } => {
                    let mut ldims = dims;
                    ldims[2] = lp.in_dim;
                    // Rank source order: manifest asi_ranks, else the
                    // shipped state tensors' shapes (so warm-start bases
                    // always fit), else a fresh default.
                    let from_state = || -> Option<Vec<usize>> {
                        let rs: Vec<usize> = (1..=3usize)
                            .filter_map(|m| {
                                let key = format!("{}.u{m}", lp.name);
                                entry
                                    .state_spec
                                    .iter()
                                    .find(|t| t.name == key)
                                    .and_then(|t| t.shape.get(1).copied())
                            })
                            .collect();
                        (rs.len() == 3).then_some(rs)
                    };
                    let ranks: Vec<usize> = entry
                        .asi_ranks
                        .get(&lp.name)
                        .cloned()
                        .filter(|r| r.len() == 3)
                        .or_else(from_state)
                        .unwrap_or_else(|| {
                            vec![ldims[0].min(4), ldims[1].min(8), ldims[2].min(16)]
                        });
                    let asi = AsiCompressor::new(&ldims, &ranks, seed_from(&lp.name));
                    let factors = WsiFactors {
                        l: Mat::zeros(lp.out_dim, k),
                        r: Mat::zeros(k, lp.in_dim),
                    };
                    LinearKind::Wasi(WasiLayer::new(factors, asi))
                }
            };
            Ok(LinearSlot { plan: lp.clone(), kind, bias: vec![0.0; lp.out_dim] })
        };

        let embed_plan = LinearPlan {
            name: "embed".into(),
            form: LinearForm::Dense,
            out_dim: plan.dim,
            in_dim: plan.patch_dim,
        };
        let head_plan = LinearPlan {
            name: "head".into(),
            form: LinearForm::Dense,
            out_dim: plan.classes,
            in_dim: plan.dim,
        };
        let mut blocks = Vec::with_capacity(plan.depth);
        for bp in &plan.blocks {
            blocks.push(BlockSlots {
                ln1: LayerNormSlot::new(plan.dim),
                qkv: build_linear(&bp[0])?,
                proj: build_linear(&bp[1])?,
                ln2: LayerNormSlot::new(plan.dim),
                fc1: build_linear(&bp[2])?,
                fc2: build_linear(&bp[3])?,
                gelu_in: None,
            });
        }
        let mut eng = NativeModelEngine {
            entry: entry.clone(),
            cls: vec![0.0; plan.dim],
            pos: vec![0.0; plan.tokens * plan.dim],
            embed: build_linear(&embed_plan)?,
            head: build_linear(&head_plan)?,
            norm: LayerNormSlot::new(plan.dim),
            blocks,
            plan,
            flat_params: params,
            flat_state: state,
        };
        eng.sync_from_flat()?;
        eng.state_into_layers()?;
        Ok(eng)
    }

    /// Copy all weights out of the flat vector into the layer structs.
    fn sync_from_flat(&mut self) -> Result<()> {
        fn slice<'a>(plan: &ModelPlan, flat: &'a [f32], name: &str) -> Result<&'a [f32]> {
            let s = plan.spec(name)?;
            Ok(&flat[s.offset..s.offset + s.numel()])
        }

        // Copies into the existing buffers (shapes are fixed at
        // construction) — no per-step allocation on the hot path.
        fn fill_slot(slot: &mut LinearSlot, plan: &ModelPlan, flat: &[f32]) -> Result<()> {
            let name = slot.plan.name.clone();
            slot.bias
                .copy_from_slice(slice(plan, flat, &format!("{name}.b"))?);
            match &mut slot.kind {
                LinearKind::Dense(d) => {
                    d.w.data
                        .copy_from_slice(slice(plan, flat, &format!("{name}.w"))?);
                }
                LinearKind::Wasi(w) => {
                    w.factors
                        .l
                        .data
                        .copy_from_slice(slice(plan, flat, &format!("{name}.l"))?);
                    w.factors
                        .r
                        .data
                        .copy_from_slice(slice(plan, flat, &format!("{name}.r"))?);
                }
            }
            Ok(())
        }

        self.cls
            .copy_from_slice(slice(&self.plan, &self.flat_params, "cls")?);
        self.pos
            .copy_from_slice(slice(&self.plan, &self.flat_params, "pos")?);
        self.norm
            .g
            .copy_from_slice(slice(&self.plan, &self.flat_params, "norm.g")?);
        self.norm
            .b
            .copy_from_slice(slice(&self.plan, &self.flat_params, "norm.b")?);
        fill_slot(&mut self.embed, &self.plan, &self.flat_params)?;
        fill_slot(&mut self.head, &self.plan, &self.flat_params)?;
        for (bi, b) in self.blocks.iter_mut().enumerate() {
            let base = format!("blocks.{bi}");
            b.ln1
                .g
                .copy_from_slice(slice(&self.plan, &self.flat_params, &format!("{base}.ln1.g"))?);
            b.ln1
                .b
                .copy_from_slice(slice(&self.plan, &self.flat_params, &format!("{base}.ln1.b"))?);
            b.ln2
                .g
                .copy_from_slice(slice(&self.plan, &self.flat_params, &format!("{base}.ln2.g"))?);
            b.ln2
                .b
                .copy_from_slice(slice(&self.plan, &self.flat_params, &format!("{base}.ln2.b"))?);
            fill_slot(&mut b.qkv, &self.plan, &self.flat_params)?;
            fill_slot(&mut b.proj, &self.plan, &self.flat_params)?;
            fill_slot(&mut b.fc1, &self.plan, &self.flat_params)?;
            fill_slot(&mut b.fc2, &self.plan, &self.flat_params)?;
        }
        Ok(())
    }

    /// Copy ASI bases out of the flat state vector into the compressors.
    fn state_into_layers(&mut self) -> Result<()> {
        if self.entry.state_spec.is_empty() {
            return Ok(());
        }
        let specs: BTreeMap<String, TensorSpec> = self
            .entry
            .state_spec
            .iter()
            .map(|t| (t.name.clone(), t.clone()))
            .collect();
        for b in &mut self.blocks {
            for slot in [&mut b.qkv, &mut b.proj, &mut b.fc1, &mut b.fc2] {
                if let LinearKind::Wasi(w) = &mut slot.kind {
                    for (m, st) in w.asi.states.iter_mut().enumerate() {
                        let key = format!("{}.u{}", slot.plan.name, m + 1);
                        if let Some(spec) = specs.get(&key) {
                            // Shipped warm-start bases must fit exactly;
                            // silently training from random init instead
                            // would be the quiet-garbage failure mode
                            // this engine refuses on principle.
                            if spec.shape != [st.u.rows, st.u.cols] {
                                bail!(
                                    "state tensor {key} shape {:?} does not match \
                                     the ASI basis ({}, {})",
                                    spec.shape, st.u.rows, st.u.cols
                                );
                            }
                            if spec.offset + spec.numel() > self.flat_state.len() {
                                bail!(
                                    "state tensor {key} [{:?} @ {}] overruns state_len {}",
                                    spec.shape, spec.offset,
                                    self.flat_state.len()
                                );
                            }
                            st.u.data.copy_from_slice(
                                &self.flat_state[spec.offset..spec.offset + spec.numel()],
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Pack the (forward-refreshed) ASI bases back into the flat state
    /// vector.  State entries that belong to layers the native engine
    /// keeps dense (the ASI-only baseline) pass through unchanged.
    fn state_from_layers(&mut self) {
        if self.entry.state_spec.is_empty() {
            return;
        }
        let specs: BTreeMap<String, TensorSpec> = self
            .entry
            .state_spec
            .iter()
            .map(|t| (t.name.clone(), t.clone()))
            .collect();
        for b in &self.blocks {
            for slot in [&b.qkv, &b.proj, &b.fc1, &b.fc2] {
                if let LinearKind::Wasi(w) = &slot.kind {
                    for (m, st) in w.asi.states.iter().enumerate() {
                        let key = format!("{}.u{}", slot.plan.name, m + 1);
                        if let Some(spec) = specs.get(&key) {
                            if spec.numel() == st.u.data.len() {
                                self.flat_state[spec.offset..spec.offset + spec.numel()]
                                    .copy_from_slice(&st.u.data);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Forward pass to logits (B, classes), saving everything backward
    /// needs inside the layer slots.
    fn forward(&mut self, x: &[f32]) -> Result<Tensor> {
        let b = self.entry.batch;
        if x.len() != b * self.entry.input_dim {
            bail!(
                "x length {} != batch {} * input_dim {}",
                x.len(), b, self.entry.input_dim
            );
        }
        let (t, d) = (self.plan.tokens, self.plan.dim);
        let patches = patchify(x, b, self.plan.image, self.plan.patch);
        let emb = self.embed.forward(&patches); // (B, G², D)

        let mut tok = vec![0.0f32; b * t * d];
        for bi in 0..b {
            tok[bi * t * d..bi * t * d + d].copy_from_slice(&self.cls);
            let src = &emb.data[bi * (t - 1) * d..(bi + 1) * (t - 1) * d];
            tok[bi * t * d + d..(bi + 1) * t * d].copy_from_slice(src);
            for (o, p) in tok[bi * t * d..(bi + 1) * t * d].iter_mut().zip(&self.pos) {
                *o += p;
            }
        }
        let mut xcur = Tensor::from_vec(&[b, t, d], tok);

        for blk in &mut self.blocks {
            // Attention-shaped dense stack: value path through the fixed
            // uniform token mixing (see module docs).
            let h = blk.ln1.forward(&xcur);
            let a = blk.qkv.forward(&h); // (B, T, 3D)
            let mut v = vec![0.0f32; b * t * d];
            for row in 0..b * t {
                v[row * d..(row + 1) * d]
                    .copy_from_slice(&a.data[row * 3 * d + 2 * d..(row + 1) * 3 * d]);
            }
            uniform_mix(&mut v, b, t, d);
            let p = blk.proj.forward(&Tensor::from_vec(&[b, t, d], v));
            for (o, pv) in xcur.data.iter_mut().zip(&p.data) {
                *o += pv;
            }
            // MLP.
            let h2 = blk.ln2.forward(&xcur);
            let f = blk.fc1.forward(&h2); // (B, T, H)
            let mut g = f.data.clone();
            for v in g.iter_mut() {
                *v = gelu(*v);
            }
            blk.gelu_in = Some(f.clone());
            let m = blk.fc2.forward(&Tensor::from_vec(&f.shape, g));
            for (o, mv) in xcur.data.iter_mut().zip(&m.data) {
                *o += mv;
            }
        }

        let z = self.norm.forward(&xcur);
        let mut cls_tok = vec![0.0f32; b * d];
        for bi in 0..b {
            cls_tok[bi * d..(bi + 1) * d].copy_from_slice(&z.data[bi * t * d..bi * t * d + d]);
        }
        Ok(self.head.forward(&Tensor::from_vec(&[b, 1, d], cls_tok)))
    }

    /// Backward from dlogits to a flat gradient vector aligned with
    /// `param_spec`.
    fn backward(&mut self, dlogits: &Tensor) -> Result<Vec<f32>> {
        let b = self.entry.batch;
        let (t, d) = (self.plan.tokens, self.plan.dim);
        // Field-disjoint borrows: the layer slots are mutated while the
        // plan is only read, so no clone is needed on the hot path.
        let plan = &self.plan;
        let mut grads = vec![0.0f32; self.entry.params_len];

        let dcls_tok = self.head.backward(dlogits, plan, &mut grads)?;

        let mut dz = vec![0.0f32; b * t * d];
        for bi in 0..b {
            dz[bi * t * d..bi * t * d + d]
                .copy_from_slice(&dcls_tok.data[bi * d..(bi + 1) * d]);
        }
        let (mut dx, dng, dnb) = self.norm.backward(&Tensor::from_vec(&[b, t, d], dz));
        write_grad(&mut grads, plan.spec("norm.g")?, &dng);
        write_grad(&mut grads, plan.spec("norm.b")?, &dnb);

        for blk in self.blocks.iter_mut().rev() {
            let base = blk.qkv.plan.name.trim_end_matches(".attn.qkv").to_string();
            // MLP branch: x2 = x1 + fc2(gelu(fc1(ln2(x1))))
            let f = blk.gelu_in.take().expect("forward before backward");
            let dg_t = blk.fc2.backward(&dx, plan, &mut grads)?; // d(gelu out)
            let mut df = dg_t;
            for (v, fv) in df.data.iter_mut().zip(&f.data) {
                *v *= gelu_grad(*fv);
            }
            let dh2 = blk.fc1.backward(&df, plan, &mut grads)?;
            let (dx1_ln, dg2, db2) = blk.ln2.backward(&dh2);
            write_grad(&mut grads, plan.spec(&format!("{base}.ln2.g"))?, &dg2);
            write_grad(&mut grads, plan.spec(&format!("{base}.ln2.b"))?, &db2);
            for (v, add) in dx.data.iter_mut().zip(&dx1_ln.data) {
                *v += add;
            }
            // Attention branch: x1 = x + proj(mix(v(qkv(ln1(x)))))
            let dv = blk.proj.backward(&dx, plan, &mut grads)?;
            // The mixing matrix (I + 11ᵀ/T)/2 is symmetric, so its
            // backward is the same operator.
            let mut dv_data = dv.data;
            uniform_mix(&mut dv_data, b, t, d);
            let mut da = vec![0.0f32; b * t * 3 * d];
            for row in 0..b * t {
                da[row * 3 * d + 2 * d..(row + 1) * 3 * d]
                    .copy_from_slice(&dv_data[row * d..(row + 1) * d]);
            }
            let dh = blk
                .qkv
                .backward(&Tensor::from_vec(&[b, t, 3 * d], da), plan, &mut grads)?;
            let (dx_ln, dg1, db1) = blk.ln1.backward(&dh);
            write_grad(&mut grads, plan.spec(&format!("{base}.ln1.g"))?, &dg1);
            write_grad(&mut grads, plan.spec(&format!("{base}.ln1.b"))?, &db1);
            for (v, add) in dx.data.iter_mut().zip(&dx_ln.data) {
                *v += add;
            }
        }

        // Token assembly: tok = concat(cls, embed) + pos.
        {
            let pos_spec = plan.spec("pos")?;
            let dpos = &mut grads[pos_spec.offset..pos_spec.offset + pos_spec.numel()];
            for bi in 0..b {
                for (g, v) in dpos
                    .iter_mut()
                    .zip(&dx.data[bi * t * d..(bi + 1) * t * d])
                {
                    *g += v;
                }
            }
        }
        {
            let cls_spec = plan.spec("cls")?;
            let dcls = &mut grads[cls_spec.offset..cls_spec.offset + cls_spec.numel()];
            for bi in 0..b {
                for (g, v) in dcls.iter_mut().zip(&dx.data[bi * t * d..bi * t * d + d]) {
                    *g += v;
                }
            }
        }
        let mut demb = vec![0.0f32; b * (t - 1) * d];
        for bi in 0..b {
            demb[bi * (t - 1) * d..(bi + 1) * (t - 1) * d]
                .copy_from_slice(&dx.data[bi * t * d + d..(bi + 1) * t * d]);
        }
        self.embed
            .backward(&Tensor::from_vec(&[b, t - 1, d], demb), plan, &mut grads)?;
        Ok(grads)
    }

    /// Clip + weight-decay + SGD + WSI refresh, mutating the flat
    /// parameter vector (mirrors the AOT step's update rule).
    fn apply_update(&mut self, grads: &[f32], lr: f32) -> Result<()> {
        let norm = grads
            .iter()
            .map(|g| (*g as f64) * (*g as f64))
            .sum::<f64>()
            .sqrt() as f32;
        let scale = if norm > GRAD_CLIP { GRAD_CLIP / norm } else { 1.0 };
        for spec in self.plan.specs.values() {
            let decay = spec.name.ends_with(".w")
                || spec.name.ends_with(".l")
                || spec.name.ends_with(".r");
            let wd = if decay { WEIGHT_DECAY } else { 0.0 };
            let lo = spec.offset;
            let hi = lo + spec.numel();
            for (p, g) in self.flat_params[lo..hi].iter_mut().zip(&grads[lo..hi]) {
                *p -= lr * (g * scale + wd * *p);
            }
        }
        // WSI refresh (Algorithm 1) on every factored layer, in flat space.
        for blist in &self.plan.blocks {
            for lp in blist {
                if let LinearForm::Factored { k } = lp.form {
                    let ls = self.plan.spec(&format!("{}.l", lp.name))?;
                    let rs = self.plan.spec(&format!("{}.r", lp.name))?;
                    let mut f = WsiFactors {
                        l: Mat::from_vec(
                            lp.out_dim,
                            k,
                            self.flat_params[ls.offset..ls.offset + ls.numel()].to_vec(),
                        ),
                        r: Mat::from_vec(
                            k,
                            lp.in_dim,
                            self.flat_params[rs.offset..rs.offset + rs.numel()].to_vec(),
                        ),
                    };
                    f.refresh();
                    self.flat_params[ls.offset..ls.offset + ls.numel()]
                        .copy_from_slice(&f.l.data);
                    self.flat_params[rs.offset..rs.offset + rs.numel()]
                        .copy_from_slice(&f.r.data);
                }
            }
        }
        Ok(())
    }

    /// Loss + accuracy + dlogits for a batch of logits.
    fn loss_and_grad(&self, logits: &Tensor, y_onehot: &[f32]) -> (f32, f32, Tensor) {
        let c = self.plan.classes;
        let b = self.entry.batch;
        let logp = log_softmax_rows(&logits.data, c);
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut dl = vec![0.0f32; logits.data.len()];
        for row in 0..b {
            let lp = &logp[row * c..(row + 1) * c];
            let y = &y_onehot[row * c..(row + 1) * c];
            let mut row_loss = 0.0f32;
            let mut label = 0usize;
            for j in 0..c {
                row_loss -= y[j] * lp[j];
                if y[j] > y[label] {
                    label = j;
                }
            }
            loss += row_loss as f64;
            let pred = (0..c)
                .max_by(|&a, &bb| lp[a].total_cmp(&lp[bb]))
                .unwrap_or(0);
            if pred == label {
                correct += 1;
            }
            for j in 0..c {
                dl[row * c + j] = (lp[j].exp() - y[j]) / b as f32;
            }
        }
        (
            (loss / b as f64) as f32,
            correct as f32 / b as f32,
            Tensor::from_vec(&logits.shape, dl),
        )
    }

    #[cfg(test)]
    fn loss_only(&mut self, x: &[f32], y_onehot: &[f32]) -> Result<f32> {
        let logits = self.forward(x)?;
        // Drop the saved activations so a later forward starts clean.
        for blk in &mut self.blocks {
            blk.gelu_in = None;
        }
        Ok(self.loss_and_grad(&logits, y_onehot).0)
    }
}

impl TrainEngine for NativeModelEngine {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn step(&mut self, x: &[f32], y_onehot: &[f32], lr: f32) -> Result<StepOutput> {
        let b = self.entry.batch;
        if y_onehot.len() != b * self.entry.classes {
            bail!("y length {} mismatch", y_onehot.len());
        }
        let logits = self.forward(x)?;
        let (loss, accuracy, dlogits) = self.loss_and_grad(&logits, y_onehot);
        let grads = self.backward(&dlogits)?;
        self.apply_update(&grads, lr)?;
        self.sync_from_flat()?;
        self.state_from_layers();
        Ok(StepOutput { loss, accuracy })
    }

    fn params(&self) -> &[f32] {
        &self.flat_params
    }

    fn state(&self) -> &[f32] {
        &self.flat_state
    }

    fn restore(&mut self, params: &[f32], state: &[f32]) -> Result<()> {
        if params.len() != self.flat_params.len() || state.len() != self.flat_state.len() {
            bail!(
                "restore shape mismatch: params {} (want {}), state {} (want {})",
                params.len(),
                self.flat_params.len(),
                state.len(),
                self.flat_state.len()
            );
        }
        self.flat_params.copy_from_slice(params);
        self.flat_state.copy_from_slice(state);
        self.sync_from_flat()?;
        self.state_into_layers()
    }

    fn backend(&self) -> &'static str {
        "native"
    }

    fn kind(&self) -> EngineKind {
        EngineKind::Native
    }
}

// ---------------------------------------------------------------------------
// Inference
// ---------------------------------------------------------------------------

/// Pure-rust inference for one ViT variant: Eq. 8 only for factored
/// layers (no ASI state, matching the lowered infer step), batch size
/// free.
pub struct NativeInferEngine {
    entry: ModelEntry,
    plan: ModelPlan,
}

impl NativeInferEngine {
    pub fn load(entry: &ModelEntry) -> Result<Self> {
        Ok(NativeInferEngine { entry: entry.clone(), plan: ModelPlan::from_entry(entry)? })
    }
}

impl InferEngine for NativeInferEngine {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn infer(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        if params.len() != self.entry.params_len {
            bail!("params length {} != manifest {}", params.len(), self.entry.params_len);
        }
        if x.len() % self.entry.input_dim != 0 {
            bail!("x length {} not a multiple of input_dim {}", x.len(), self.entry.input_dim);
        }
        let b = x.len() / self.entry.input_dim;
        let plan = &self.plan;
        let (t, d) = (plan.tokens, plan.dim);
        let get = |name: &str| -> Result<&[f32]> {
            let s = plan.spec(name)?;
            Ok(&params[s.offset..s.offset + s.numel()])
        };
        // Weights are copied out of the caller's flat vector per call
        // (params may be a live trainer's, changing between calls, so
        // nothing can be cached).  The copy is O(weight) while the
        // matmul it feeds is O(weight · rows) — ≥2 orders of magnitude
        // larger at any real batch — so per-call copies do not skew the
        // latency exhibits measured through this path.
        let linear = |lp: &LinearPlan, x: &Mat| -> Result<Mat> {
            let mut y = match lp.form {
                LinearForm::Dense => {
                    let w = Mat::from_vec(lp.out_dim, lp.in_dim,
                                          get(&format!("{}.w", lp.name))?.to_vec());
                    x.matmul_nt(&w)
                }
                LinearForm::Factored { k } => {
                    let l = Mat::from_vec(lp.out_dim, k, get(&format!("{}.l", lp.name))?.to_vec());
                    let r = Mat::from_vec(k, lp.in_dim, get(&format!("{}.r", lp.name))?.to_vec());
                    x.matmul_nt(&r).matmul_nt(&l)
                }
            };
            let bias = get(&format!("{}.b", lp.name))?;
            for chunk in y.data.chunks_mut(lp.out_dim) {
                for (v, bv) in chunk.iter_mut().zip(bias) {
                    *v += bv;
                }
            }
            Ok(y)
        };
        let layer_norm = |x: &mut Mat, g: &[f32], bb: &[f32]| {
            let dd = x.cols;
            for row in x.data.chunks_mut(dd) {
                let mu = row.iter().sum::<f32>() / dd as f32;
                let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / dd as f32;
                let is = 1.0 / (var + LN_EPS).sqrt();
                for c in 0..dd {
                    row[c] = (row[c] - mu) * is * g[c] + bb[c];
                }
            }
        };

        let patches = patchify(x, b, plan.image, plan.patch);
        let embed_plan = LinearPlan {
            name: "embed".into(),
            form: LinearForm::Dense,
            out_dim: d,
            in_dim: plan.patch_dim,
        };
        let emb = linear(&embed_plan, &Mat::from_vec(b * (t - 1), plan.patch_dim,
                                                     patches.data))?;
        let cls = get("cls")?;
        let pos = get("pos")?;
        let mut tok = Mat::zeros(b * t, d);
        for bi in 0..b {
            tok.data[bi * t * d..bi * t * d + d].copy_from_slice(cls);
            tok.data[bi * t * d + d..(bi + 1) * t * d]
                .copy_from_slice(&emb.data[bi * (t - 1) * d..(bi + 1) * (t - 1) * d]);
            for (o, p) in tok.data[bi * t * d..(bi + 1) * t * d].iter_mut().zip(pos) {
                *o += p;
            }
        }

        for (bi, bp) in plan.blocks.iter().enumerate() {
            let base = format!("blocks.{bi}");
            let mut h = tok.clone();
            layer_norm(&mut h, get(&format!("{base}.ln1.g"))?, get(&format!("{base}.ln1.b"))?);
            let a = linear(&bp[0], &h)?; // (rows, 3D)
            let mut v = Mat::zeros(b * t, d);
            for row in 0..b * t {
                v.data[row * d..(row + 1) * d]
                    .copy_from_slice(&a.data[row * 3 * d + 2 * d..(row + 1) * 3 * d]);
            }
            uniform_mix(&mut v.data, b, t, d);
            let p = linear(&bp[1], &v)?;
            for (o, pv) in tok.data.iter_mut().zip(&p.data) {
                *o += pv;
            }
            let mut h2 = tok.clone();
            layer_norm(&mut h2, get(&format!("{base}.ln2.g"))?, get(&format!("{base}.ln2.b"))?);
            let mut f = linear(&bp[2], &h2)?;
            for vv in f.data.iter_mut() {
                *vv = gelu(*vv);
            }
            let m = linear(&bp[3], &f)?;
            for (o, mv) in tok.data.iter_mut().zip(&m.data) {
                *o += mv;
            }
        }

        layer_norm(&mut tok, get("norm.g")?, get("norm.b")?);
        let mut cls_tok = Mat::zeros(b, d);
        for bi in 0..b {
            cls_tok.data[bi * d..(bi + 1) * d]
                .copy_from_slice(&tok.data[bi * t * d..bi * t * d + d]);
        }
        let head_plan = LinearPlan {
            name: "head".into(),
            form: LinearForm::Dense,
            out_dim: plan.classes,
            in_dim: d,
        };
        Ok(linear(&head_plan, &cls_tok)?.data)
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::super::demo::{write_demo_artifacts, DemoConfig};
    use super::*;
    use crate::data::synth::VisionTask;
    use crate::runtime::Manifest;

    fn demo_manifest(tag: &str) -> Manifest {
        let dir = std::env::temp_dir().join(format!("wasi_engine_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn plan_reconstructs_demo_vit() {
        let m = demo_manifest("plan");
        let entry = m.model("vit_demo_wasi_eps80").unwrap();
        let plan = ModelPlan::from_entry(entry).unwrap();
        assert_eq!(plan.image * plan.image * 3, entry.input_dim);
        assert_eq!(plan.classes, entry.classes);
        assert_eq!(plan.blocks.len(), plan.depth);
        // mlp linears factored, attention dense in the demo fixture
        for b in &plan.blocks {
            assert_eq!(b[0].form, LinearForm::Dense);
            assert!(matches!(b[2].form, LinearForm::Factored { .. }));
            assert!(matches!(b[3].form, LinearForm::Factored { .. }));
        }
    }

    #[test]
    fn plan_refuses_unknown_tensor() {
        let m = demo_manifest("refuse");
        let mut entry = m.model("vit_demo_vanilla").unwrap().clone();
        entry.param_spec.push(TensorSpec {
            name: "blocks.0.frobnicator.w".into(),
            shape: vec![1],
            offset: 0,
        });
        let err = ModelPlan::from_entry(&entry).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("frobnicator"), "{msg}");
    }

    #[test]
    fn plan_refuses_non_vit_spec() {
        let m = demo_manifest("nonvit");
        let mut entry = m.model("vit_demo_vanilla").unwrap().clone();
        // TinyDec-style spec: no patch-embed scaffolding.
        entry.param_spec = vec![TensorSpec {
            name: "tok_embed".into(),
            shape: vec![16, 8],
            offset: 0,
        }];
        assert!(ModelPlan::from_entry(&entry).is_err());
    }

    #[test]
    fn tensor_roundtrips_offsets_and_shapes() {
        let m = demo_manifest("roundtrip");
        let entry = m.model("vit_demo_wasi_eps80").unwrap();
        let eng = NativeModelEngine::load(entry).unwrap();
        let initial = entry.load_params().unwrap();
        // Construction must not perturb the flat vector.
        assert_eq!(eng.params(), &initial[..]);
        for spec in &entry.param_spec {
            let (data, shape) = eng.tensor(&spec.name).unwrap();
            assert_eq!(shape, spec.shape, "{}", spec.name);
            assert_eq!(data, &initial[spec.offset..spec.offset + spec.numel()]);
        }
        // Restore round-trip.
        let mut eng = eng;
        let state = entry.load_state().unwrap();
        eng.restore(&initial, &state).unwrap();
        assert_eq!(eng.params(), &initial[..]);
        assert_eq!(eng.state(), &state[..]);
    }

    #[test]
    fn grads_match_finite_differences() {
        let m = demo_manifest("fd");
        let entry = m.model("vit_demo_vanilla").unwrap();
        let mut eng = NativeModelEngine::load(entry).unwrap();
        let mut task = VisionTask::new("fd", entry.classes, 16, 0.5, 4, 3);
        let (x, y, _) = task.batch_onehot(entry.batch);

        let logits = eng.forward(&x).unwrap();
        let (_, _, dlogits) = eng.loss_and_grad(&logits, &y);
        let grads = eng.backward(&dlogits).unwrap();

        // Probe a spread of tensors: embed, attn value column, mlp, ln,
        // cls/pos, head.
        let probes = [
            ("embed.w", 3usize),
            ("blocks.0.mlp.fc1.w", 7),
            ("blocks.1.attn.proj.w", 11),
            ("blocks.0.ln2.g", 2),
            ("cls", 5),
            ("pos", 13),
            ("head.w", 1),
            ("head.b", 0),
        ];
        let h = 1e-2f32;
        let base = eng.params().to_vec();
        let state = eng.state().to_vec();
        for (name, k) in probes {
            let spec = eng.plan.spec(name).unwrap().clone();
            let idx = spec.offset + k.min(spec.numel() - 1);
            let mut up = base.clone();
            up[idx] += h;
            eng.restore(&up, &state).unwrap();
            let lp = eng.loss_only(&x, &y).unwrap();
            let mut dn = base.clone();
            dn[idx] -= h;
            eng.restore(&dn, &state).unwrap();
            let lm = eng.loss_only(&x, &y).unwrap();
            let fd = (lp - lm) / (2.0 * h);
            let an = grads[idx];
            assert!(
                (fd - an).abs() < 2e-2 * fd.abs().max(1.0),
                "{name}[{k}]: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn training_reduces_loss_on_both_parameterizations() {
        // Repeated steps on one fixed batch: the loss must fall
        // decisively (2.x -> ~1.6 in the numpy oracle of this math).
        let m = demo_manifest("train");
        for model in ["vit_demo_vanilla", "vit_demo_wasi_eps80"] {
            let entry = m.model(model).unwrap();
            let mut eng = NativeModelEngine::load(entry).unwrap();
            let mut task = VisionTask::new("t", entry.classes, 16, 0.5, 4, 233);
            let (x, y, _) = task.batch_onehot(entry.batch);
            let mut losses = Vec::new();
            for _ in 0..16 {
                let out = eng.step(&x, &y, 0.1).unwrap();
                assert!(out.loss.is_finite(), "{model}: loss must stay finite");
                losses.push(out.loss);
            }
            let head: f32 = losses[..4].iter().sum::<f32>() / 4.0;
            let tail: f32 = losses[12..].iter().sum::<f32>() / 4.0;
            assert!(
                tail < head * 0.9,
                "{model}: loss should fall decisively ({losses:?})"
            );
        }
    }

    #[test]
    fn infer_matches_train_engine_forward_at_load() {
        let m = demo_manifest("infer");
        let entry = m.model("vit_demo_vanilla").unwrap();
        let mut eng = NativeModelEngine::load(entry).unwrap();
        let infer = NativeInferEngine::load(entry).unwrap();
        let mut task = VisionTask::new("i", entry.classes, 16, 0.5, 4, 9);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let train_logits = eng.forward(&x).unwrap();
        for blk in &mut eng.blocks {
            blk.gelu_in = None;
        }
        let infer_logits = infer.infer(eng.params(), &x).unwrap();
        assert_eq!(train_logits.data.len(), infer_logits.len());
        for (a, b) in train_logits.data.iter().zip(&infer_logits) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}

//! Backend-agnostic execution engines: the one training/inference
//! surface the coordinator, CLI, eval harness, and examples program
//! against (DESIGN.md §4).
//!
//! Two implementations exist behind the [`TrainEngine`] / [`InferEngine`]
//! traits:
//!
//! * [`HloTrainEngine`] / [`HloInferEngine`] (`hlo` module) — thin
//!   wrappers over the AOT-compiled HLO steps (`runtime::TrainStep`,
//!   `runtime::InferStep`), executed through whichever runtime backend
//!   is live (PJRT, or the native kernel fallback).
//! * [`NativeModelEngine`] / [`NativeInferEngine`] (`native` module) —
//!   full-model training in pure rust: the manifest's `param_spec` is
//!   parsed into a typed layer-graph IR (`graph` module: plan → node
//!   program → executor) whose nodes run against the flat parameter
//!   vector through the shared kernel layer (`linalg::kernels`), so the
//!   default (PJRT-free) build fine-tunes end to end.
//!
//! [`EngineKind`] is the selection policy; `auto` prefers HLO when the
//! runtime can execute model HLO and falls back to the native engine
//! otherwise, which is what makes `--engine auto` work identically in
//! every build configuration.

pub mod demo;
pub mod graph;
mod hlo;
mod native;
pub mod ops;
pub mod passes;

use std::str::FromStr;

use anyhow::{anyhow, Result};

use crate::precision::Precision;
use crate::runtime::{ModelEntry, Runtime, StepOutput};

pub use graph::{
    DeltaOverlay, GraphExecutor, LayerGraph, LinearForm, LinearPlan, ModelPlan, Node, NodeTiming,
    PackedParams, PlanReport, ProgramReport, QuantTensor, StoredTensor,
};
pub use hlo::{HloInferEngine, HloTrainEngine};
pub use native::{NativeInferEngine, NativeModelEngine};
pub use ops::{Op, UpdateOp};

/// One training backend for one model variant.
///
/// The contract matches the AOT train step:
/// `(params, state, x, y_onehot, lr) -> (loss, acc)` with the flat
/// params/state vectors owned by the engine and readable between steps
/// (checkpointing, validation, tensor inspection).
///
/// `Send` is a supertrait: the job service (`crate::serve`) hands train
/// engines to worker threads, one engine exclusively per job.  Both
/// implementations qualify — the native engine owns plain buffers, the
/// HLO engine borrows a runtime whose backends are `Sync` (executable
/// caches behind mutexes).
pub trait TrainEngine: Send {
    /// The manifest entry this engine was built from.
    fn entry(&self) -> &ModelEntry;

    /// One SGD step on a batch.  `x` is (batch, input_dim) flat,
    /// `y_onehot` is (batch, classes) flat.
    fn step(&mut self, x: &[f32], y_onehot: &[f32], lr: f32) -> Result<StepOutput>;

    /// Current flat parameter vector (length `entry().params_len`).
    fn params(&self) -> &[f32];

    /// Current flat ASI state vector (length `entry().state_len`).
    fn state(&self) -> &[f32];

    /// Overwrite params/state (checkpoint restore).  Lengths must match.
    fn restore(&mut self, params: &[f32], state: &[f32]) -> Result<()>;

    /// Slice one named tensor out of the flat parameter vector.  `None`
    /// for unknown names or specs that overrun the vector (corrupt
    /// manifest) — never panics.
    fn tensor(&self, name: &str) -> Option<(&[f32], Vec<usize>)> {
        let spec = self.entry().param_tensor(name)?.clone();
        let n = spec.numel();
        let params = self.params();
        if spec.offset + n > params.len() {
            return None;
        }
        Some((&params[spec.offset..spec.offset + n], spec.shape))
    }

    /// Short backend label for logs/reports (`"hlo"` / `"native"`).
    fn backend(&self) -> &'static str;

    /// The concrete kind this engine implements — lets callers build a
    /// matching inference engine without string-matching `backend()`.
    fn kind(&self) -> EngineKind;

    /// Restrict training to the WASI subspace (`persist:"delta"` jobs,
    /// DESIGN.md §Variant store): only the factored layers' `.l`/`.r`
    /// tensors update, everything else stays bit-identical to the
    /// loaded base.  Returns the trainable element count.  The default
    /// refuses — only the native engine controls its optimizer ranges.
    fn restrict_to_subspace(&mut self) -> Result<usize> {
        Err(anyhow!(
            "the {} engine cannot restrict training to the subspace; \
             delta persistence requires --engine native (or auto)",
            self.backend()
        ))
    }
}

/// One inference backend for one model variant:
/// `(params, x) -> logits`, params supplied explicitly so a live
/// trainer's parameters can be validated without copies.
///
/// `Send + Sync` are supertraits: inference engines are stateless
/// between calls (`infer` takes `&self`), so the job service shares one
/// engine per variant across all concurrent requests.
pub trait InferEngine: Send + Sync {
    fn entry(&self) -> &ModelEntry;

    /// Run on a batch with explicit params (usually `TrainEngine::params`).
    fn infer(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>>;

    /// Argmax labels for a batch (NaN-safe: a diverged run must surface
    /// as bad accuracy, not a panic).
    fn predict(&self, params: &[f32], x: &[f32]) -> Result<Vec<usize>> {
        let logits = self.infer(params, x)?;
        Ok(ops::argmax_rows(&logits, self.entry().classes))
    }

    fn backend(&self) -> &'static str;
}

/// Engine selection policy (the CLI's `--engine {auto|hlo|native}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum EngineKind {
    /// Prefer HLO when the runtime can execute model HLO; fall back to
    /// the native full-model engine otherwise.
    #[default]
    Auto,
    /// Force the AOT/HLO path (errors without an HLO-capable backend).
    Hlo,
    /// Force the pure-rust full-model engine.
    Native,
}

impl FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<EngineKind> {
        match s {
            "auto" => Ok(EngineKind::Auto),
            "hlo" => Ok(EngineKind::Hlo),
            "native" => Ok(EngineKind::Native),
            other => Err(anyhow!("unknown engine {other:?}; expected auto, hlo, or native")),
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EngineKind::Auto => "auto",
            EngineKind::Hlo => "hlo",
            EngineKind::Native => "native",
        })
    }
}

impl EngineKind {
    /// Resolve `Auto` against a concrete runtime: HLO when the backend
    /// can execute model HLO programs, the native engine otherwise.
    pub fn resolve(self, rt: &Runtime) -> EngineKind {
        match self {
            EngineKind::Auto => {
                if rt.can_execute_hlo() {
                    EngineKind::Hlo
                } else {
                    EngineKind::Native
                }
            }
            k => k,
        }
    }
}

/// Build the selected training engine for one model variant.
pub fn train_engine<'rt>(
    rt: &'rt Runtime,
    entry: &ModelEntry,
    kind: EngineKind,
) -> Result<Box<dyn TrainEngine + 'rt>> {
    train_engine_with(rt, entry, kind, Precision::F32)
}

/// [`train_engine`] with an explicit weight-storage precision.  The
/// HLO engine executes the AOT-lowered f32 step and cannot honor a
/// reduced storage format, so bf16 requires the native engine; int8 is
/// inference-only and refused by the native engine itself.
pub fn train_engine_with<'rt>(
    rt: &'rt Runtime,
    entry: &ModelEntry,
    kind: EngineKind,
    precision: Precision,
) -> Result<Box<dyn TrainEngine + 'rt>> {
    // `auto` also falls back to native when the variant ships no train
    // artifact — the native engine trains from `param_spec` alone.
    let resolved = match kind {
        EngineKind::Auto if entry.train_hlo.is_none() => EngineKind::Native,
        EngineKind::Auto if precision != Precision::F32 => EngineKind::Native,
        k => k.resolve(rt),
    };
    match resolved {
        EngineKind::Hlo if precision != Precision::F32 => Err(anyhow!(
            "precision {precision} requires the native engine; the HLO step is f32-only \
             (use --engine native or --engine auto)"
        )),
        EngineKind::Hlo => Ok(Box::new(HloTrainEngine::load(rt, entry)?)),
        _ => Ok(Box::new(NativeModelEngine::load_with(entry, precision)?)),
    }
}

/// Build the selected inference engine for one model variant.
pub fn infer_engine<'rt>(
    rt: &'rt Runtime,
    entry: &ModelEntry,
    kind: EngineKind,
) -> Result<Box<dyn InferEngine + 'rt>> {
    // Mirror train_engine's rule: a variant shipping no train artifact
    // is a native-first artifact set (the AOT pipeline always emits
    // train+infer pairs), so `auto` serves its inference natively too
    // instead of compiling its placeholder infer HLO.
    let resolved = match kind {
        EngineKind::Auto if entry.train_hlo.is_none() => EngineKind::Native,
        k => k.resolve(rt),
    };
    match resolved {
        EngineKind::Hlo => Ok(Box::new(HloInferEngine::load(rt, entry)?)),
        _ => Ok(Box::new(NativeInferEngine::load(entry)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parses() {
        assert_eq!("auto".parse::<EngineKind>().unwrap(), EngineKind::Auto);
        assert_eq!("hlo".parse::<EngineKind>().unwrap(), EngineKind::Hlo);
        assert_eq!("native".parse::<EngineKind>().unwrap(), EngineKind::Native);
        assert!("cuda".parse::<EngineKind>().is_err());
    }

    #[test]
    fn auto_resolves_to_native_without_pjrt() {
        let rt = Runtime::native();
        assert_eq!(EngineKind::Auto.resolve(&rt), EngineKind::Native);
        assert_eq!(EngineKind::Hlo.resolve(&rt), EngineKind::Hlo);
        assert_eq!(EngineKind::Native.resolve(&rt), EngineKind::Native);
    }
}

//! HLO engine: the AOT-compiled train/infer steps behind the engine
//! traits.  Behavior-preserving wrappers over `runtime::TrainStep` /
//! `runtime::InferStep` — all compute happens inside the lowered HLO
//! program, executed by whichever runtime backend is live.

use anyhow::{anyhow, Result};

use crate::runtime::{InferStep, ModelEntry, Runtime, StepOutput, TrainStep};

use super::{EngineKind, InferEngine, TrainEngine};

/// Training through the variant's compiled train-step artifact.
pub struct HloTrainEngine<'rt> {
    step: TrainStep<'rt>,
}

impl<'rt> HloTrainEngine<'rt> {
    pub fn load(rt: &'rt Runtime, entry: &ModelEntry) -> Result<Self> {
        Ok(HloTrainEngine { step: TrainStep::load(rt, entry)? })
    }
}

impl TrainEngine for HloTrainEngine<'_> {
    fn entry(&self) -> &ModelEntry {
        &self.step.entry
    }

    fn step(&mut self, x: &[f32], y_onehot: &[f32], lr: f32) -> Result<StepOutput> {
        self.step.step(x, y_onehot, lr)
    }

    fn params(&self) -> &[f32] {
        &self.step.params
    }

    fn state(&self) -> &[f32] {
        &self.step.state
    }

    fn restore(&mut self, params: &[f32], state: &[f32]) -> Result<()> {
        if params.len() != self.step.params.len() || state.len() != self.step.state.len() {
            return Err(anyhow!(
                "restore shape mismatch: params {} (want {}), state {} (want {})",
                params.len(),
                self.step.params.len(),
                state.len(),
                self.step.state.len()
            ));
        }
        self.step.params.copy_from_slice(params);
        self.step.state.copy_from_slice(state);
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "hlo"
    }

    fn kind(&self) -> EngineKind {
        EngineKind::Hlo
    }
}

/// Inference through the variant's compiled infer artifact.
pub struct HloInferEngine<'rt> {
    step: InferStep<'rt>,
}

impl<'rt> HloInferEngine<'rt> {
    pub fn load(rt: &'rt Runtime, entry: &ModelEntry) -> Result<Self> {
        Ok(HloInferEngine { step: InferStep::load(rt, entry)? })
    }
}

impl InferEngine for HloInferEngine<'_> {
    fn entry(&self) -> &ModelEntry {
        &self.step.entry
    }

    fn infer(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        self.step.infer(params, x)
    }

    fn backend(&self) -> &'static str {
        "hlo"
    }
}

//! The native layer-graph IR (DESIGN.md §4): plan-then-execute.
//!
//! **Plan** — [`ModelPlan::from_entry`] parses a manifest entry's flat
//! `param_spec` back into the ViT-tiny architecture the AOT pipeline
//! lowered (patch embed → CLS/pos → transformer blocks → final norm →
//! head) and refuses any tensor name it does not recognize — a
//! wrong-model manifest fails loudly instead of training garbage.
//! [`LayerGraph::from_plan`] then emits the typed node program
//! (`ops::Op` forward chain + `ops::UpdateOp` optimizer program) once.
//!
//! **Execute** — [`GraphExecutor`] resolves every node to concrete
//! tensor offsets at construction (no per-step name formatting or map
//! lookups) and runs forward/backward/update straight against the flat
//! parameter vector through the shared kernel layer
//! (`linalg::kernels`): weights are never copied into per-layer
//! structs, dense weight gradients are GEMM'd directly into the flat
//! gradient vector, and bias adds are fused into the GEMM epilogue.
//! Per-node wallclock is accumulated when profiling is on, which is
//! what `eval::latency::node_attribution` and `wasi-train bench` tag
//! instead of re-deriving shapes.
//!
//! **Documented substitution (DESIGN.md §4):** inside each block the
//! softmax attention matrix is replaced by the fixed doubly-stochastic
//! mixing `(I + 11ᵀ/T)/2` applied to the value path
//! (`qkv → v → mix → proj`) — an attention-shaped dense stack.  The
//! trainable linears, their shapes, the residual structure, the
//! activation-memory profile, and the patch→CLS information flow are
//! identical to the lowered model; only the mixing weights (which the
//! softmax computes from q/k and which carry no trainable parameters of
//! their own) are fixed, so the q/k columns of `qkv.w` receive zero
//! gradient.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::linalg::kernels::{self, Epilogue, PackedPanel};
use crate::linalg::matrix::Mat;
use crate::linalg::tucker::Tensor;
use crate::precision::{self, Precision};
use crate::runtime::{ModelEntry, TensorSpec};
use crate::wasi::asi::{AsiCompressor, CompressedActivation};
use crate::wasi::lowrank_grad::lowrank_grad_3d;
use crate::wasi::wsi::WsiFactors;

use super::ops::{self, Op, UpdateOp};
use super::passes::{self, BufRange, Interval, Liveness, PassSet};

/// Mirrors the AOT pipeline's training hyperparameters
/// (`python/compile/train.py`): global-norm clip and decoupled weight
/// decay on `.w`/`.l`/`.r` tensors only.
const GRAD_CLIP: f32 = 2.0;
const WEIGHT_DECAY: f32 = 1e-4;

// ---------------------------------------------------------------------------
// Plan: param_spec -> architecture
// ---------------------------------------------------------------------------

/// How one linear layer is parameterized in the flat vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LinearForm {
    /// `{prefix}.w` (O, I)
    Dense,
    /// `{prefix}.l` (O, K) + `{prefix}.r` (K, I)
    Factored { k: usize },
}

/// One linear layer recovered from the spec.
#[derive(Debug, Clone)]
pub struct LinearPlan {
    pub name: String,
    pub form: LinearForm,
    pub out_dim: usize,
    pub in_dim: usize,
}

/// The ViT architecture reconstructed from a manifest entry's
/// `param_spec` (see `python/compile/model.py::init_vit` for the
/// authoritative naming).
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub dim: usize,
    pub depth: usize,
    pub tokens: usize,
    pub patch: usize,
    pub image: usize,
    pub patch_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    /// Per block: qkv, proj, fc1, fc2.
    pub blocks: Vec<[LinearPlan; 4]>,
    specs: BTreeMap<String, TensorSpec>,
}

fn isqrt(n: usize) -> Option<usize> {
    let r = (n as f64).sqrt().round() as usize;
    (r * r == n).then_some(r)
}

impl ModelPlan {
    /// Parse a `param_spec` back into the ViT layer graph.  Every tensor
    /// name must be accounted for; unknown names (SwinLite stages,
    /// TinyDec token embeddings, corrupt specs) are refused.
    pub fn from_entry(entry: &ModelEntry) -> Result<ModelPlan> {
        if entry.param_spec.is_empty() {
            bail!(
                "model {}: manifest entry has no param_spec; the native \
                 engine cannot reconstruct the layer graph",
                entry.name
            );
        }
        let mut specs = BTreeMap::new();
        for t in &entry.param_spec {
            if t.offset + t.numel() > entry.params_len {
                bail!(
                    "model {}: tensor {} [{:?} @ {}] overruns params_len {}",
                    entry.name,
                    t.name,
                    t.shape,
                    t.offset,
                    entry.params_len
                );
            }
            if specs.insert(t.name.clone(), t.clone()).is_some() {
                bail!("model {}: duplicate param_spec tensor {}", entry.name, t.name);
            }
        }
        let get = |name: &str| -> Result<&TensorSpec> {
            specs.get(name).ok_or_else(|| {
                anyhow!("model {}: param_spec is missing tensor {name:?}", entry.name)
            })
        };

        // Fixed scaffolding tensors.
        let embed = get("embed.w")?;
        if embed.shape.len() != 2 {
            bail!("embed.w must be (D, patch_dim), got {:?}", embed.shape);
        }
        let (dim, patch_dim) = (embed.shape[0], embed.shape[1]);
        let pos = get("pos")?;
        if pos.shape.len() != 3 || pos.shape[0] != 1 || pos.shape[2] != dim {
            bail!("pos must be (1, tokens, {dim}), got {:?}", pos.shape);
        }
        let tokens = pos.shape[1];
        if tokens < 2 {
            bail!("pos token count {tokens} too small for CLS + patches");
        }
        let cls = get("cls")?;
        if cls.shape != [1, 1, dim] {
            bail!("cls must be (1, 1, {dim}), got {:?}", cls.shape);
        }
        let head = get("head.w")?;
        if head.shape.len() != 2 || head.shape[1] != dim {
            bail!("head.w must be (classes, {dim}), got {:?}", head.shape);
        }
        let classes = head.shape[0];
        if classes != entry.classes {
            bail!("head.w rows {} != manifest classes {}", classes, entry.classes);
        }
        let patch = isqrt(patch_dim / 3)
            .filter(|p| p * p * 3 == patch_dim)
            .ok_or_else(|| anyhow!("patch_dim {patch_dim} is not 3·p²"))?;
        let grid = isqrt(tokens - 1)
            .ok_or_else(|| anyhow!("tokens {tokens} is not g²+1"))?;
        let image = grid * patch;
        if image * image * 3 != entry.input_dim {
            bail!(
                "reconstructed image {image}x{image}x3 != manifest input_dim {}",
                entry.input_dim
            );
        }

        // Blocks: contiguous indices, each with the full layer set.
        let mut depth = 0;
        for name in specs.keys() {
            if let Some(rest) = name.strip_prefix("blocks.") {
                let idx: usize = rest
                    .split('.')
                    .next()
                    .unwrap_or("")
                    .parse()
                    .map_err(|_| anyhow!("bad block tensor name {name:?}"))?;
                depth = depth.max(idx + 1);
            }
        }
        if depth == 0 {
            bail!("model {}: param_spec has no blocks.* tensors", entry.name);
        }

        let linear_plan = |prefix: &str, o: usize, i: usize| -> Result<LinearPlan> {
            let b = get(&format!("{prefix}.b"))?;
            if b.shape != [o] {
                bail!("{prefix}.b must be ({o},), got {:?}", b.shape);
            }
            if let Some(w) = specs.get(&format!("{prefix}.w")) {
                if w.shape != [o, i] {
                    bail!("{prefix}.w must be ({o}, {i}), got {:?}", w.shape);
                }
                return Ok(LinearPlan {
                    name: prefix.to_string(),
                    form: LinearForm::Dense,
                    out_dim: o,
                    in_dim: i,
                });
            }
            let l = get(&format!("{prefix}.l"))?;
            let r = get(&format!("{prefix}.r"))?;
            if l.shape.len() != 2 || r.shape.len() != 2 || l.shape[0] != o
                || r.shape[1] != i || l.shape[1] != r.shape[0]
            {
                bail!(
                    "{prefix}: factored shapes l {:?} / r {:?} inconsistent with ({o}, {i})",
                    l.shape,
                    r.shape
                );
            }
            Ok(LinearPlan {
                name: prefix.to_string(),
                form: LinearForm::Factored { k: l.shape[1] },
                out_dim: o,
                in_dim: i,
            })
        };

        let mut hidden = 0;
        let mut blocks = Vec::with_capacity(depth);
        for b in 0..depth {
            let p = format!("blocks.{b}");
            for ln in ["ln1", "ln2"] {
                for gb in ["g", "b"] {
                    let t = get(&format!("{p}.{ln}.{gb}"))?;
                    if t.shape != [dim] {
                        bail!("{p}.{ln}.{gb} must be ({dim},), got {:?}", t.shape);
                    }
                }
            }
            let fc1 = {
                // hidden comes from the first block's fc1 output.
                let probe = specs
                    .get(&format!("{p}.mlp.fc1.w"))
                    .or_else(|| specs.get(&format!("{p}.mlp.fc1.l")))
                    .ok_or_else(|| anyhow!("{p}.mlp.fc1 has neither .w nor .l"))?;
                let h = probe.shape.first().copied().unwrap_or(0);
                if hidden == 0 {
                    hidden = h;
                }
                linear_plan(&format!("{p}.mlp.fc1"), hidden, dim)?
            };
            blocks.push([
                linear_plan(&format!("{p}.attn.qkv"), 3 * dim, dim)?,
                linear_plan(&format!("{p}.attn.proj"), dim, dim)?,
                fc1,
                linear_plan(&format!("{p}.mlp.fc2"), dim, hidden)?,
            ]);
        }
        for suffix in ["norm.g", "norm.b"] {
            let t = get(suffix)?;
            if t.shape != [dim] {
                bail!("{suffix} must be ({dim},), got {:?}", t.shape);
            }
        }
        let hb = get("head.b")?;
        if hb.shape != [classes] {
            bail!("head.b must be ({classes},), got {:?}", hb.shape);
        }
        let eb = get("embed.b")?;
        if eb.shape != [dim] {
            bail!("embed.b must be ({dim},), got {:?}", eb.shape);
        }

        // Grammar closure: the spec must contain exactly the tensors
        // the reconstructed plan accounts for — the expected-name set is
        // generated from the plan itself, so the grammar lives in one
        // place.  (Missing tensors already failed above via `get`.)
        let mut expected: std::collections::BTreeSet<String> = [
            "embed.w", "embed.b", "cls", "pos", "norm.g", "norm.b", "head.w", "head.b",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        for (bi, blist) in blocks.iter().enumerate() {
            for ln in ["ln1", "ln2"] {
                for gb in ["g", "b"] {
                    expected.insert(format!("blocks.{bi}.{ln}.{gb}"));
                }
            }
            for lp in blist {
                expected.insert(format!("{}.b", lp.name));
                match lp.form {
                    LinearForm::Dense => {
                        expected.insert(format!("{}.w", lp.name));
                    }
                    LinearForm::Factored { .. } => {
                        expected.insert(format!("{}.l", lp.name));
                        expected.insert(format!("{}.r", lp.name));
                    }
                }
            }
        }
        for name in specs.keys() {
            if !expected.contains(name) {
                bail!(
                    "model {}: param_spec tensor {name:?} is not part of the \
                     ViT layer grammar; the native engine refuses to guess \
                     (only vit_* variants are reconstructable)",
                    entry.name
                );
            }
        }

        Ok(ModelPlan {
            dim, depth, tokens, patch, image, patch_dim, hidden, classes,
            blocks,
            specs,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&TensorSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| anyhow!("no tensor {name:?} in plan"))
    }

    /// Find the block linear with this prefix (`blocks.N.mlp.fc1`, …).
    pub fn linear(&self, name: &str) -> Option<&LinearPlan> {
        self.blocks.iter().flatten().find(|lp| lp.name == name)
    }

    /// The `param_spec` entries that live inside the WASI subspace —
    /// the factored linears' `.l`/`.r` tensors, in flat-offset order.
    /// These are exactly the tensors a variant-store delta record
    /// persists (DESIGN.md §Variant store); every other tensor belongs
    /// to the shared frozen base.
    pub fn subspace_specs(&self) -> Vec<TensorSpec> {
        let mut out = Vec::new();
        for lp in self.blocks.iter().flatten() {
            if matches!(lp.form, LinearForm::Factored { .. }) {
                for suffix in ["l", "r"] {
                    if let Some(spec) = self.specs.get(&format!("{}.{suffix}", lp.name)) {
                        out.push(spec.clone());
                    }
                }
            }
        }
        out.sort_unstable_by_key(|s| s.offset);
        out
    }
}

fn seed_from(name: &str) -> u64 {
    // FNV-1a over the layer name: deterministic ASI init when the
    // manifest ships no state vector.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// The planned graph
// ---------------------------------------------------------------------------

/// One planned forward node.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: Op,
    /// Trailing (feature) dimension this node outputs.
    pub out_features: usize,
}

/// The planned node program: forward chain + optimizer program, built
/// ONCE from the manifest (`plan-then-execute`).
pub struct LayerGraph {
    pub plan: ModelPlan,
    pub nodes: Vec<Node>,
    pub updates: Vec<UpdateOp>,
}

fn linear_op(lp: &LinearPlan) -> Op {
    match lp.form {
        LinearForm::Dense => Op::Dense { name: lp.name.clone() },
        LinearForm::Factored { k } => Op::Wasi { name: lp.name.clone(), k },
    }
}

impl LayerGraph {
    pub fn from_entry(entry: &ModelEntry) -> Result<LayerGraph> {
        Ok(Self::from_plan(ModelPlan::from_entry(entry)?))
    }

    /// Emit the node program for a reconstructed plan.
    pub fn from_plan(plan: ModelPlan) -> LayerGraph {
        let d = plan.dim;
        let mut nodes: Vec<Node> = Vec::new();
        let mut push = |op: Op, f: usize| nodes.push(Node { op, out_features: f });
        push(Op::Patchify, plan.patch_dim);
        push(Op::Dense { name: "embed".into() }, d);
        push(Op::Assemble, d);
        for (bi, blk) in plan.blocks.iter().enumerate() {
            let base = format!("blocks.{bi}");
            push(Op::ResidualSave, d);
            push(Op::LayerNorm { name: format!("{base}.ln1") }, d);
            push(linear_op(&blk[0]), 3 * d);
            push(Op::SliceV, d);
            push(Op::Mixing, d);
            push(linear_op(&blk[1]), d);
            push(Op::ResidualAdd, d);
            push(Op::ResidualSave, d);
            push(Op::LayerNorm { name: format!("{base}.ln2") }, d);
            push(linear_op(&blk[2]), plan.hidden);
            push(Op::Gelu, plan.hidden);
            push(linear_op(&blk[3]), d);
            push(Op::ResidualAdd, d);
        }
        push(Op::LayerNorm { name: "norm".into() }, d);
        push(Op::TakeCls, d);
        push(Op::Dense { name: "head".into() }, plan.classes);
        push(Op::SoftmaxCe, plan.classes);

        let mut updates = vec![UpdateOp::SgdClipDecay];
        for blk in &plan.blocks {
            for lp in blk {
                if matches!(lp.form, LinearForm::Factored { .. }) {
                    updates.push(UpdateOp::WsiRefresh { name: lp.name.clone() });
                }
            }
        }
        LayerGraph { plan, nodes, updates }
    }
}

// ---------------------------------------------------------------------------
// Packed (reduced-precision) parameter sets
// ---------------------------------------------------------------------------

/// One int8-quantized weight tensor: per-tensor symmetric payload plus
/// its dequantization scale (DESIGN.md §Precision).
pub struct QuantTensor {
    pub q: Vec<i8>,
    pub scale: f32,
}

/// One tensor in a [`PackedParams`] set.
pub enum StoredTensor {
    F32(Vec<f32>),
    /// bf16 bits (`crate::precision::bf16_to_f32` recovers the value).
    Bf16(Vec<u16>),
    I8(QuantTensor),
}

impl StoredTensor {
    /// Payload bytes this tensor occupies in the packed representation.
    pub fn bytes(&self) -> usize {
        match self {
            StoredTensor::F32(d) => d.len() * 4,
            StoredTensor::Bf16(d) => d.len() * 2,
            StoredTensor::I8(t) => t.q.len() + 4,
        }
    }
}

/// A packed parameter set for reduced-precision inference: every 2-D
/// GEMM weight tensor (`.w` / `.l` / `.r`) is stored at the selected
/// [`Precision`], everything else (biases, norms, cls/pos) stays f32.
/// Built once per variant by quantize-on-load (`serve::pool`) so
/// cached shared infer engines serve from the compact representation.
pub struct PackedParams {
    precision: Precision,
    /// Tensors keyed by their flat-vector offset (the executor's
    /// resolved bindings address tensors by offset).
    tensors: BTreeMap<usize, StoredTensor>,
    params_len: usize,
    /// Prepacked panels for reduced-precision GEMM weights, keyed by
    /// flat offset (the `prepack` pass — built once at pack time so
    /// the inference hot path never re-dequantizes a B panel).  bf16
    /// weights pack as f32 images, int8 as raw quantized bytes for the
    /// true-integer GEMM.
    panels: BTreeMap<usize, PackedPanel>,
    /// The `fold` pass's precomputed `cls + pos` assembly constant
    /// (`pos`-shaped; the first `dim` elements carry the folded CLS
    /// row).  Both tensors are frozen in a packed set, so the fold is
    /// exact: the runtime add it replaces is the same single f32 add.
    assemble_const: Option<Vec<f32>>,
}

fn is_gemm_weight(spec: &TensorSpec) -> bool {
    spec.shape.len() == 2
        && (spec.name.ends_with(".w") || spec.name.ends_with(".l") || spec.name.ends_with(".r"))
}

impl PackedParams {
    /// Pack a flat f32 parameter vector at `precision`.  `F32` packs
    /// losslessly (useful for tests); `Bf16`/`I8` compress the GEMM
    /// weight tensors.
    pub fn pack(entry: &ModelEntry, params: &[f32], prec: Precision) -> Result<PackedParams> {
        Self::pack_with(entry, params, prec, passes::current_passes()?)
    }

    /// [`PackedParams::pack`] with an explicit pass set: `prepack`
    /// controls whether f32 panels are built for reduced-precision
    /// weights, `fold` whether the `cls + pos` assembly constant is
    /// precomputed.  Both representations are bit-exact alternates, so
    /// disabling a pass only changes where the work happens.
    pub fn pack_with(
        entry: &ModelEntry,
        params: &[f32],
        prec: Precision,
        passes: PassSet,
    ) -> Result<PackedParams> {
        if params.len() != entry.params_len {
            bail!(
                "params length {} != manifest {} — packing another model's vector?",
                params.len(),
                entry.params_len
            );
        }
        let mut tensors = BTreeMap::new();
        let mut panels = BTreeMap::new();
        for spec in &entry.param_spec {
            let data = &params[spec.offset..spec.offset + spec.numel()];
            let stored = if is_gemm_weight(spec) {
                match prec {
                    Precision::F32 => StoredTensor::F32(data.to_vec()),
                    Precision::Bf16 => StoredTensor::Bf16(precision::pack_bf16(data)),
                    Precision::I8 => {
                        let (q, scale) = precision::quantize_i8(data);
                        StoredTensor::I8(QuantTensor { q, scale })
                    }
                }
            } else {
                StoredTensor::F32(data.to_vec())
            };
            if passes.prepack() && is_gemm_weight(spec) {
                let (n, k) = (spec.shape[0], spec.shape[1]);
                match &stored {
                    StoredTensor::Bf16(d) => {
                        panels.insert(spec.offset, PackedPanel::pack(d, n, k));
                    }
                    // int8 panels keep RAW quantized bytes (1 B/elem,
                    // ~¼ of an f32 image) and route to the true-integer
                    // GEMM; the scale travels inside the panel.
                    StoredTensor::I8(t) => {
                        panels.insert(spec.offset, PackedPanel::pack_i8(&t.q, n, k, t.scale));
                    }
                    // f32 weights feed `gemm_nt` directly (B rows are
                    // already contiguous f32) — nothing to prepack.
                    StoredTensor::F32(_) => {}
                }
            }
            if tensors.insert(spec.offset, stored).is_some() {
                bail!("model {}: param_spec offsets collide at {}", entry.name, spec.offset);
            }
        }
        let assemble_const = if passes.fold() {
            let cls = entry.param_spec.iter().find(|s| s.name == "cls");
            let pos = entry.param_spec.iter().find(|s| s.name == "pos");
            match (cls, pos) {
                (Some(c), Some(p)) if c.numel() <= p.numel() => {
                    // folded[j] = cls[j] + pos[j] for the CLS row, the
                    // remaining rows keep pos verbatim — exactly the add
                    // the runtime Assemble performs.
                    let mut v = params[p.offset..p.offset + p.numel()].to_vec();
                    let cv = &params[c.offset..c.offset + c.numel()];
                    for (o, a) in v.iter_mut().zip(cv) {
                        *o = *a + *o;
                    }
                    Some(v)
                }
                _ => None,
            }
        } else {
            None
        };
        Ok(PackedParams {
            precision: prec,
            tensors,
            params_len: entry.params_len,
            panels,
            assemble_const,
        })
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn params_len(&self) -> usize {
        self.params_len
    }

    /// Total payload bytes of the packed representation (the number the
    /// memory accounting and the bench's precision section report).
    pub fn bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes()).sum()
    }

    /// Bytes held by prepacked f32 panels (the `prepack` pass's memory
    /// cost, reported by the bench's passes section).  Zero when the
    /// pass is disabled or the precision is f32.
    pub fn panel_bytes(&self) -> usize {
        self.panels.values().map(|p| p.bytes()).sum()
    }

    /// Number of prepacked panels in this set.
    pub fn panel_count(&self) -> usize {
        self.panels.len()
    }

    /// Whether the `fold` pass precomputed the Assemble constant.
    pub fn has_folded_assemble(&self) -> bool {
        self.assemble_const.is_some()
    }

    fn stored(&self, spec: &TensorSpec) -> Result<&StoredTensor> {
        self.tensors
            .get(&spec.offset)
            .ok_or_else(|| anyhow!("no packed tensor at offset {} ({})", spec.offset, spec.name))
    }
}

/// A borrowed weight tensor as the inference walk sees it.
pub enum WeightView<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    I8(&'a [i8], f32),
    /// A prepacked panel of a reduced-precision weight (the `prepack`
    /// pass) — an f32 image for bf16, raw quantized bytes + scale for
    /// int8.  Carries its own dims; scales are applied intrinsically
    /// by `gemm_nt_prepacked`.
    Panel(&'a PackedPanel),
}

/// A zero-copy personalized parameter view: the shared frozen base
/// with a variant's subspace factor tensors overlaid (DESIGN.md
/// §Variant store).  Tensors are keyed by flat-vector offset, the same
/// addressing the executor's resolved bindings use, so the inference
/// walk reads factors from the overlay and everything else straight
/// from the base — the full personalized vector is never materialized.
pub struct DeltaOverlay<'a> {
    base: &'a [f32],
    tensors: BTreeMap<usize, &'a [f32]>,
}

impl<'a> DeltaOverlay<'a> {
    /// Build an overlay after bounds-checking every tensor against the
    /// base vector (a record from another model's store would otherwise
    /// read garbage offsets).
    pub fn new(
        base: &'a [f32],
        tensors: BTreeMap<usize, &'a [f32]>,
    ) -> Result<DeltaOverlay<'a>> {
        for (offset, data) in &tensors {
            if offset + data.len() > base.len() {
                bail!(
                    "overlay tensor [{} @ {offset}] overruns base params_len {}",
                    data.len(),
                    base.len()
                );
            }
        }
        Ok(DeltaOverlay { base, tensors })
    }

    fn slice(&self, spec: &TensorSpec) -> Result<&'a [f32]> {
        match self.tensors.get(&spec.offset) {
            Some(d) if d.len() == spec.numel() => Ok(d),
            Some(d) => bail!(
                "overlay tensor at offset {} has {} elements, spec {} wants {}",
                spec.offset,
                d.len(),
                spec.name,
                spec.numel()
            ),
            None => Ok(&self.base[spec.offset..spec.offset + spec.numel()]),
        }
    }
}

/// The parameter source an inference walk reads from: the flat f32
/// vector (training params, checkpoints), a packed reduced-precision
/// set, or the frozen base with a delta overlay.  Copyable so the walk
/// threads it by value.
#[derive(Clone, Copy)]
pub enum ParamsView<'a> {
    Flat(&'a [f32]),
    Packed(&'a PackedParams),
    Overlay(&'a DeltaOverlay<'a>),
}

impl<'a> ParamsView<'a> {
    fn len(self) -> usize {
        match self {
            ParamsView::Flat(p) => p.len(),
            ParamsView::Packed(p) => p.params_len,
            ParamsView::Overlay(o) => o.base.len(),
        }
    }

    /// An f32 tensor (biases, norms, cls/pos — never quantized).
    fn floats(self, spec: &TensorSpec) -> Result<&'a [f32]> {
        match self {
            ParamsView::Flat(p) => Ok(&p[spec.offset..spec.offset + spec.numel()]),
            ParamsView::Packed(p) => match p.stored(spec)? {
                StoredTensor::F32(d) => Ok(d),
                _ => bail!("tensor {} is packed at reduced precision, expected f32", spec.name),
            },
            ParamsView::Overlay(o) => o.slice(spec),
        }
    }

    /// A GEMM weight tensor at whatever precision it is stored.
    fn weight(self, spec: &TensorSpec) -> Result<WeightView<'a>> {
        match self {
            ParamsView::Flat(p) => {
                Ok(WeightView::F32(&p[spec.offset..spec.offset + spec.numel()]))
            }
            ParamsView::Packed(p) => {
                if let Some(panel) = p.panels.get(&spec.offset) {
                    return Ok(WeightView::Panel(panel));
                }
                Ok(match p.stored(spec)? {
                    StoredTensor::F32(d) => WeightView::F32(d),
                    StoredTensor::Bf16(d) => WeightView::Bf16(d),
                    StoredTensor::I8(t) => WeightView::I8(&t.q, t.scale),
                })
            }
            ParamsView::Overlay(o) => Ok(WeightView::F32(o.slice(spec)?)),
        }
    }
}

/// One linear layer forward for the inference walk: `out = x · Wᵀ`
/// (+ bias, optionally fused GELU), dispatching on the weight's storage
/// precision — f32 and bf16 dequantize in the inner loop at scale 1;
/// int8 runs the TRUE-integer `gemm_nt_i8` (activations quantize
/// per-row, i8×i8→i32 dots, scales applied intrinsically in the
/// epilogue), so every storage form takes the same plain epilogue.
fn linear_nt(
    w: WeightView,
    x: &[f32],
    rows: usize,
    i: usize,
    o: usize,
    bias: Option<&[f32]>,
    fuse_gelu: bool,
    out: &mut [f32],
) {
    let plain_epi = match (bias, fuse_gelu) {
        (Some(b), true) => Epilogue::BiasGelu(b),
        (Some(b), false) => Epilogue::Bias(b),
        (None, true) => Epilogue::Gelu,
        (None, false) => Epilogue::None,
    };
    match w {
        WeightView::F32(wf) => kernels::gemm_nt(x, wf, rows, i, o, out, plain_epi),
        WeightView::Bf16(wq) => kernels::gemm_nt_deq(x, wq, rows, i, o, out, plain_epi),
        WeightView::I8(wq, scale) => {
            kernels::gemm_nt_i8(x, wq, rows, i, o, scale, out, plain_epi)
        }
        // Panels dispatch on their payload internally: bf16 images run
        // the f32 path, i8 panels the integer path — both with scales
        // already final/intrinsic, so the plain epilogue is correct
        // for every panel form.
        WeightView::Panel(p) => kernels::gemm_nt_prepacked(x, p, rows, out, plain_epi),
    }
}

// ---------------------------------------------------------------------------
// Execution: resolved bindings + per-node context
// ---------------------------------------------------------------------------

/// A node resolved to concrete flat-vector offsets (done once at
/// executor construction — the hot loop never formats names or walks
/// maps).
enum Bind {
    Patchify,
    Assemble { cls: TensorSpec, pos: TensorSpec },
    LayerNorm { g: TensorSpec, b: TensorSpec },
    Dense { w: TensorSpec, b: TensorSpec, o: usize, i: usize, needs_dx: bool },
    Wasi {
        name: String,
        l: TensorSpec,
        r: TensorSpec,
        b: TensorSpec,
        o: usize,
        k: usize,
        i: usize,
    },
    SliceV,
    Mixing,
    Gelu,
    ResidualSave,
    ResidualAdd,
    TakeCls,
    SoftmaxCe,
}

/// What forward saved for backward.
enum Saved {
    None,
    /// Linear input activation (dense layers).
    X(Vec<f32>),
    /// Layer norm normalization stats.
    Ln { xhat: Vec<f32>, inv_std: Vec<f32> },
    /// ASI-compressed input + rank-space intermediate (WASI layers).
    Wasi { comp: CompressedActivation, h: Vec<f32> },
    /// GELU pre-activation.
    Gelu(Vec<f32>),
}

struct Slot {
    label: String,
    out_features: usize,
    bind: Bind,
    asi: Option<AsiCompressor>,
    saved: Saved,
    fwd_s: f64,
    bwd_s: f64,
    calls: usize,
}

/// Resolved optimizer step.
enum UpdateStep {
    Sgd { ranges: Vec<(usize, usize, f32)> },
    Refresh { l: TensorSpec, r: TensorSpec, o: usize, k: usize, i: usize },
}

/// Per-node accumulated wallclock (the latency-attribution tags).
#[derive(Debug, Clone)]
pub struct NodeTiming {
    pub label: String,
    pub out_features: usize,
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub calls: usize,
}

fn build_asi(entry: &ModelEntry, plan: &ModelPlan, name: &str) -> Result<AsiCompressor> {
    let lp = plan
        .linear(name)
        .ok_or_else(|| anyhow!("no linear plan for factored layer {name:?}"))?;
    let dims = [entry.batch, plan.tokens, lp.in_dim];
    // Rank source order: manifest asi_ranks, else the shipped state
    // tensors' shapes (so warm-start bases always fit), else a fresh
    // default.
    let from_state = || -> Option<Vec<usize>> {
        let rs: Vec<usize> = (1..=3usize)
            .filter_map(|m| {
                let key = format!("{name}.u{m}");
                entry
                    .state_spec
                    .iter()
                    .find(|t| t.name == key)
                    .and_then(|t| t.shape.get(1).copied())
            })
            .collect();
        (rs.len() == 3).then_some(rs)
    };
    let ranks: Vec<usize> = entry
        .asi_ranks
        .get(name)
        .cloned()
        .filter(|r| r.len() == 3)
        .or_else(from_state)
        .unwrap_or_else(|| vec![dims[0].min(4), dims[1].min(8), dims[2].min(16)]);
    Ok(AsiCompressor::new(&dims, &ranks, seed_from(name)))
}

// ---------------------------------------------------------------------------
// Pass pipeline: planned buffer programs (the `arena` pass)
// ---------------------------------------------------------------------------

/// One planned step's arena ranges, in elements.  Meaning is per-op:
/// `src` is the walk's current buffer at entry, `out` at exit (equal
/// for in-place ops), `a`/`b` are op-specific extras (rank-space
/// intermediates, norm stats, residual copies).  Zero-length ranges
/// mean "not used by this op".
#[derive(Clone, Copy)]
struct StepBufs {
    src: BufRange,
    out: BufRange,
    a: BufRange,
    b: BufRange,
}

const NOB: BufRange = BufRange { off: 0, len: 0 };

/// A planned buffer program: the mechanical mirror of one executor walk
/// with every transient `Vec` replaced by an offset into one arena.
/// The planner simulates the walk exactly (same size formulas, same
/// stack discipline), so the runtime mirror performs the identical
/// kernel calls in the identical order on identically-sized buffers —
/// which is the bit-identity argument (DESIGN.md §Pass pipeline).
struct PlannedProgram {
    /// Per-slot forward ranges (for inference: per batch element).
    steps: Vec<StepBufs>,
    /// Per-slot backward ranges (training programs only).
    bwd: Vec<StepBufs>,
    /// Backward's incoming dlogits buffer (training programs only).
    dl0: BufRange,
    /// The walk's result buffer (logits).
    out: BufRange,
    /// Total arena length.
    arena_elems: usize,
    /// Sum of all interval lengths (the no-reuse footprint).
    sum_elems: usize,
    intervals: Vec<Interval>,
    offsets: Vec<usize>,
}

fn elems_of(lv: &Liveness, id: Option<usize>) -> usize {
    id.map(|i| lv.intervals()[i].elems).unwrap_or(0)
}

/// Liveness-plan one executor walk.  `train` plans forward + backward
/// on the executor's fixed batch with saved activations pinned across
/// the loss boundary; `!train` plans the inference walk per batch
/// element (every inference buffer scales linearly with `b`, so the
/// runtime multiplies offsets by the call's batch).
fn plan_program(
    slots: &[Slot],
    plan: &ModelPlan,
    batch: usize,
    train: bool,
) -> Result<PlannedProgram> {
    let n = slots.len();
    let b = if train { batch } else { 1 };
    let (t, d) = (plan.tokens, plan.dim);
    let pd = plan.patch_dim;
    let classes = plan.classes;
    // Timeline: forward slot si at time si, loss at n, backward slot si
    // at 2n - si (reverse order, after the loss) — saved activations
    // get `touch`ed at their backward time so they stay live across the
    // whole round trip.
    let bwd_t = |si: usize| 2 * n - si;
    let mut lv = Liveness::new();
    let mut fwd_ids: Vec<[Option<usize>; 4]> = vec![[None; 4]; n];
    let mut bwd_ids: Vec<[Option<usize>; 4]> = vec![[None; 4]; n];
    let mut cur: Option<usize> = None;
    let mut rows = 0usize; // token-row count of `cur`
    let mut stack: Vec<usize> = Vec::new();
    for (si, slot) in slots.iter().enumerate() {
        let prev = cur;
        let src_of = |p: Option<usize>| {
            p.ok_or_else(|| anyhow!("planner: {} has no input buffer", slot.label))
        };
        match &slot.bind {
            Bind::Patchify => {
                cur = Some(lv.alloc(si, b * (t - 1) * pd));
                rows = b * (t - 1);
            }
            Bind::Dense { o, .. } => {
                let src = src_of(prev)?;
                lv.touch(src, si);
                if train {
                    lv.touch(src, bwd_t(si)); // saved X
                }
                cur = Some(lv.alloc(si, rows * o));
            }
            Bind::Wasi { o, k, .. } => {
                let src = src_of(prev)?;
                lv.touch(src, si);
                let h = lv.alloc(si, rows * k);
                if train {
                    lv.touch(h, bwd_t(si)); // saved rank-space intermediate
                }
                fwd_ids[si][2] = Some(h);
                cur = Some(lv.alloc(si, rows * o));
            }
            Bind::Assemble { .. } => {
                lv.touch(src_of(prev)?, si);
                cur = Some(lv.alloc(si, b * t * d));
                rows = b * t;
            }
            Bind::LayerNorm { g, .. } => {
                let src = src_of(prev)?;
                lv.touch(src, si);
                let dd = g.numel();
                if train {
                    let xhat = lv.alloc(si, rows * dd);
                    lv.touch(xhat, bwd_t(si));
                    let inv = lv.alloc(si, rows);
                    lv.touch(inv, bwd_t(si));
                    fwd_ids[si][2] = Some(xhat);
                    fwd_ids[si][3] = Some(inv);
                    cur = Some(lv.alloc(si, rows * dd));
                }
                // Inference normalizes in place.
            }
            Bind::SliceV => {
                lv.touch(src_of(prev)?, si);
                cur = Some(lv.alloc(si, rows * d));
            }
            Bind::Mixing => {
                lv.touch(src_of(prev)?, si); // in place
            }
            Bind::Gelu => {
                let src = src_of(prev)?;
                lv.touch(src, si);
                if train {
                    lv.touch(src, bwd_t(si)); // saved pre-activation
                    let len = lv.intervals()[src].elems;
                    cur = Some(lv.alloc(si, len));
                }
                // Inference applies GELU in place (or fuses it away).
            }
            Bind::ResidualSave => {
                let src = src_of(prev)?;
                lv.touch(src, si);
                let cpy = lv.alloc(si, lv.intervals()[src].elems);
                stack.push(cpy);
                fwd_ids[si][2] = Some(cpy);
            }
            Bind::ResidualAdd => {
                lv.touch(src_of(prev)?, si);
                let res = stack
                    .pop()
                    .ok_or_else(|| anyhow!("planner: residual stack underflow"))?;
                lv.touch(res, si);
                fwd_ids[si][2] = Some(res);
            }
            Bind::TakeCls => {
                lv.touch(src_of(prev)?, si);
                cur = Some(lv.alloc(si, b * d));
                rows = b;
            }
            Bind::SoftmaxCe => {
                lv.touch(src_of(prev)?, si);
            }
        }
        fwd_ids[si][0] = prev;
        fwd_ids[si][1] = cur;
    }
    let out_id = cur.ok_or_else(|| anyhow!("planner: empty node program"))?;
    lv.touch(out_id, n); // logits are read out after the walk

    let mut dl0_id = None;
    if train {
        let dl = lv.alloc(n + 1, b * classes);
        dl0_id = Some(dl);
        let mut dcur: Option<usize> = Some(dl);
        let mut dstack: Vec<usize> = Vec::new();
        for si in (0..n).rev() {
            let tt = bwd_t(si);
            let dprev = dcur;
            if let Some(id) = dcur {
                lv.touch(id, tt);
            }
            match &slots[si].bind {
                Bind::SoftmaxCe | Bind::Gelu | Bind::Mixing => {} // in place
                Bind::Dense { needs_dx, .. } => {
                    if *needs_dx {
                        dcur = Some(lv.alloc(tt, elems_of(&lv, fwd_ids[si][0])));
                    } else {
                        dcur = None;
                    }
                }
                Bind::Wasi { .. } => {
                    let dh = lv.alloc(tt, elems_of(&lv, fwd_ids[si][2]));
                    bwd_ids[si][2] = Some(dh);
                    dcur = Some(lv.alloc(tt, elems_of(&lv, fwd_ids[si][0])));
                }
                Bind::LayerNorm { g, .. } => {
                    let dd = g.numel();
                    let dg = lv.alloc(tt, dd);
                    let db = lv.alloc(tt, dd);
                    bwd_ids[si][2] = Some(dg);
                    bwd_ids[si][3] = Some(db);
                    dcur = Some(lv.alloc(tt, elems_of(&lv, dprev)));
                }
                Bind::SliceV | Bind::TakeCls | Bind::Assemble { .. } => {
                    dcur = Some(lv.alloc(tt, elems_of(&lv, fwd_ids[si][0])));
                }
                Bind::ResidualAdd => {
                    let cpy = lv.alloc(tt, elems_of(&lv, dprev));
                    dstack.push(cpy);
                    bwd_ids[si][2] = Some(cpy);
                }
                Bind::ResidualSave => {
                    let dres = dstack
                        .pop()
                        .ok_or_else(|| anyhow!("planner: residual dstack underflow"))?;
                    lv.touch(dres, tt);
                    bwd_ids[si][2] = Some(dres);
                }
                Bind::Patchify => {
                    dcur = None;
                }
            }
            bwd_ids[si][0] = dprev;
            bwd_ids[si][1] = dcur;
        }
    }

    let intervals = lv.intervals().to_vec();
    let layout = passes::assign_offsets(&intervals);
    passes::check_disjoint(&intervals, &layout)?;
    let mk = |id: Option<usize>| {
        id.map(|i| BufRange { off: layout.offsets[i], len: intervals[i].elems })
            .unwrap_or(NOB)
    };
    let to_bufs = |ids: &[[Option<usize>; 4]]| -> Vec<StepBufs> {
        ids.iter()
            .map(|s| StepBufs { src: mk(s[0]), out: mk(s[1]), a: mk(s[2]), b: mk(s[3]) })
            .collect()
    };
    Ok(PlannedProgram {
        steps: to_bufs(&fwd_ids),
        bwd: if train { to_bufs(&bwd_ids) } else { Vec::new() },
        dl0: mk(dl0_id),
        out: mk(Some(out_id)),
        arena_elems: layout.total,
        sum_elems: intervals.iter().map(|iv| iv.elems).sum(),
        offsets: layout.offsets,
        intervals,
    })
}

/// The executor's planned programs (present when the `arena` pass is
/// enabled).
struct OptPrograms {
    /// Training round trip; `None` on inference-only executors.
    train: Option<PlannedProgram>,
    infer: PlannedProgram,
}

/// A planned program's reportable shape (the `plan` subcommand and the
/// bench's passes section).
#[derive(Debug, Clone)]
pub struct ProgramReport {
    /// Arena length in elements (for inference: per batch element).
    pub arena_elems: usize,
    /// Sum of all planned buffer lengths — what one walk would touch
    /// without arena reuse.
    pub sum_elems: usize,
    /// Number of planned buffers.
    pub buffers: usize,
    /// `(def, last, elems, offset)` per buffer, in allocation order.
    pub intervals: Vec<(usize, usize, usize, usize)>,
}

/// What [`GraphExecutor::plan_report`] exposes about the pass pipeline.
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub passes: PassSet,
    pub train: Option<ProgramReport>,
    pub infer: Option<ProgramReport>,
}

// Unchecked arena views.  Safety: every (write, read) pair a planned
// arm materializes comes from one planned program whose pairwise
// disjointness `passes::check_disjoint` verified at construction, and
// the unbound lifetime never escapes the executing method, where the
// arena is held alive by a local.
unsafe fn ar<'a>(p: *const f32, r: BufRange) -> &'a [f32] {
    std::slice::from_raw_parts(p.add(r.off), r.len)
}
unsafe fn aw<'a>(p: *mut f32, r: BufRange) -> &'a mut [f32] {
    std::slice::from_raw_parts_mut(p.add(r.off), r.len)
}

thread_local! {
    /// Per-thread inference arena: the infer walk is `&self` on shared
    /// (pool-cached) engines, so its arena cannot live in the executor.
    static INFER_ARENA: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Per-thread mixing scratch for the planned infer walk.
    static INFER_MEAN: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Executes a [`LayerGraph`] against flat parameter/gradient vectors
/// through the shared kernel layer.
pub struct GraphExecutor {
    graph: LayerGraph,
    slots: Vec<Slot>,
    updates: Vec<UpdateStep>,
    state_spec: Vec<TensorSpec>,
    state_len: usize,
    batch: usize,
    input_dim: usize,
    params_len: usize,
    profiling: bool,
    /// When set ([`GraphExecutor::restrict_to_subspace`]) the SGD pass
    /// touches only the factored layers' `.l`/`.r` ranges and the clip
    /// norm is computed over those ranges alone.
    subspace_only: bool,
    /// The optimization passes this executor was planned with.
    passes: PassSet,
    /// Planned buffer programs (`arena` pass); `None` disables the
    /// planned walks entirely.
    opt: Option<OptPrograms>,
    /// `true` while a planned forward's saved state sits in the arena —
    /// backward must then take the planned path regardless of profiling
    /// (the two paths store saved activations differently).
    fwd_was_planned: bool,
    /// Training arena + reusable scratch (ASI input / dH tensor
    /// staging, mixing mean).  Capacity is retained across steps, so
    /// steady-state training allocates nothing here.
    train_arena: Vec<f32>,
    scratch_x: Vec<f32>,
    scratch_dh: Vec<f32>,
    scratch_mean: Vec<f32>,
}

impl GraphExecutor {
    /// Training executor: resolves bindings AND builds the per-layer
    /// ASI compressors.  Plans under the process-wide pass set
    /// ([`passes::current_passes`]).
    pub fn new(graph: LayerGraph, entry: &ModelEntry) -> Result<GraphExecutor> {
        Self::build(graph, entry, true, passes::current_passes()?)
    }

    /// Inference-only executor: skips the (training-only) ASI
    /// compressor construction.  `forward_train` on this executor
    /// panics at the first factored layer; use [`GraphExecutor::infer`].
    pub fn new_infer(graph: LayerGraph, entry: &ModelEntry) -> Result<GraphExecutor> {
        Self::build(graph, entry, false, passes::current_passes()?)
    }

    /// [`GraphExecutor::new`] with an explicit pass set (tests pin
    /// optimized-vs-unoptimized bit-identity through this).
    pub fn new_with(graph: LayerGraph, entry: &ModelEntry, ps: PassSet) -> Result<GraphExecutor> {
        Self::build(graph, entry, true, ps)
    }

    /// [`GraphExecutor::new_infer`] with an explicit pass set.
    pub fn new_infer_with(
        graph: LayerGraph,
        entry: &ModelEntry,
        ps: PassSet,
    ) -> Result<GraphExecutor> {
        Self::build(graph, entry, false, ps)
    }

    fn build(
        graph: LayerGraph,
        entry: &ModelEntry,
        with_asi: bool,
        ps: PassSet,
    ) -> Result<GraphExecutor> {
        let plan = &graph.plan;
        let mut slots = Vec::with_capacity(graph.nodes.len());
        let mut prev_op: Option<&Op> = None;
        for node in &graph.nodes {
            let bind = match &node.op {
                Op::Patchify => Bind::Patchify,
                Op::Assemble => Bind::Assemble {
                    cls: plan.spec("cls")?.clone(),
                    pos: plan.spec("pos")?.clone(),
                },
                Op::LayerNorm { name } => Bind::LayerNorm {
                    g: plan.spec(&format!("{name}.g"))?.clone(),
                    b: plan.spec(&format!("{name}.b"))?.clone(),
                },
                Op::Dense { name } => {
                    let w = plan.spec(&format!("{name}.w"))?.clone();
                    let b = plan.spec(&format!("{name}.b"))?.clone();
                    let (o, i) = (w.shape[0], w.shape[1]);
                    // The linear fed by Patchify needs no input grads.
                    let needs_dx = !matches!(prev_op, Some(Op::Patchify));
                    Bind::Dense { w, b, o, i, needs_dx }
                }
                Op::Wasi { name, k } => {
                    let l = plan.spec(&format!("{name}.l"))?.clone();
                    let r = plan.spec(&format!("{name}.r"))?.clone();
                    let b = plan.spec(&format!("{name}.b"))?.clone();
                    let (o, i) = (l.shape[0], r.shape[1]);
                    Bind::Wasi { name: name.clone(), l, r, b, o, k: *k, i }
                }
                Op::SliceV => Bind::SliceV,
                Op::Mixing => Bind::Mixing,
                Op::Gelu => Bind::Gelu,
                Op::ResidualSave => Bind::ResidualSave,
                Op::ResidualAdd => Bind::ResidualAdd,
                Op::TakeCls => Bind::TakeCls,
                Op::SoftmaxCe => Bind::SoftmaxCe,
            };
            let asi = match &node.op {
                Op::Wasi { name, .. } if with_asi => Some(build_asi(entry, plan, name)?),
                _ => None,
            };
            slots.push(Slot {
                label: node.op.label(),
                out_features: node.out_features,
                bind,
                asi,
                saved: Saved::None,
                fwd_s: 0.0,
                bwd_s: 0.0,
                calls: 0,
            });
            prev_op = Some(&node.op);
        }

        let mut updates = Vec::with_capacity(graph.updates.len());
        for u in &graph.updates {
            match u {
                UpdateOp::SgdClipDecay => {
                    let mut ranges = Vec::with_capacity(graph.plan.specs.len());
                    for spec in graph.plan.specs.values() {
                        let decay = spec.name.ends_with(".w")
                            || spec.name.ends_with(".l")
                            || spec.name.ends_with(".r");
                        let wd = if decay { WEIGHT_DECAY } else { 0.0 };
                        ranges.push((spec.offset, spec.offset + spec.numel(), wd));
                    }
                    updates.push(UpdateStep::Sgd { ranges });
                }
                UpdateOp::WsiRefresh { name } => {
                    let l = graph.plan.spec(&format!("{name}.l"))?.clone();
                    let r = graph.plan.spec(&format!("{name}.r"))?.clone();
                    let (o, k, i) = (l.shape[0], l.shape[1], r.shape[1]);
                    updates.push(UpdateStep::Refresh { l, r, o, k, i });
                }
            }
        }

        let scratch_mean = vec![0.0f32; graph.plan.dim];
        let mut exec = GraphExecutor {
            slots,
            updates,
            state_spec: entry.state_spec.clone(),
            state_len: entry.state_len,
            batch: entry.batch,
            input_dim: entry.input_dim,
            params_len: entry.params_len,
            profiling: false,
            subspace_only: false,
            passes: ps,
            opt: None,
            fwd_was_planned: false,
            train_arena: Vec::new(),
            scratch_x: Vec::new(),
            scratch_dh: Vec::new(),
            scratch_mean,
            graph,
        };
        if ps.arena() {
            let infer = plan_program(&exec.slots, &exec.graph.plan, exec.batch, false)?;
            let train = if with_asi {
                Some(plan_program(&exec.slots, &exec.graph.plan, exec.batch, true)?)
            } else {
                None
            };
            exec.opt = Some(OptPrograms { train, infer });
        }
        Ok(exec)
    }

    fn train_prog(&self) -> Option<&PlannedProgram> {
        self.opt.as_ref().and_then(|o| o.train.as_ref())
    }

    /// The pass set this executor was planned with.
    pub fn passes(&self) -> PassSet {
        self.passes
    }

    /// Reportable shape of the planned programs (the `plan` subcommand
    /// and the bench's passes section); `train`/`infer` are `None` when
    /// the `arena` pass is disabled or the executor is inference-only.
    pub fn plan_report(&self) -> PlanReport {
        let mk = |p: &PlannedProgram| ProgramReport {
            arena_elems: p.arena_elems,
            sum_elems: p.sum_elems,
            buffers: p.intervals.len(),
            intervals: p
                .intervals
                .iter()
                .map(|iv| (iv.def, iv.last, iv.elems, p.offsets[iv.id]))
                .collect(),
        };
        PlanReport {
            passes: self.passes,
            train: self.train_prog().map(mk),
            infer: self.opt.as_ref().map(|o| mk(&o.infer)),
        }
    }

    /// Restrict training to the WASI subspace: after this call the SGD
    /// pass updates ONLY the factored layers' `.l`/`.r` tensors (the
    /// WSI refreshes already stay inside the subspace), so every other
    /// tensor remains bit-identical to the loaded base — the contract
    /// the variant store's delta records rely on (`persist:"delta"`,
    /// DESIGN.md §Variant store).  Returns the trainable element count.
    pub fn restrict_to_subspace(&mut self) -> Result<usize> {
        let specs = self.graph.plan.subspace_specs();
        if specs.is_empty() {
            bail!(
                "model has no factored (subspace) layers; subspace-only \
                 training requires a wasi variant"
            );
        }
        let ranges: Vec<(usize, usize, f32)> = specs
            .iter()
            .map(|s| (s.offset, s.offset + s.numel(), WEIGHT_DECAY))
            .collect();
        let trainable = ranges.iter().map(|(lo, hi, _)| hi - lo).sum();
        for step in &mut self.updates {
            if let UpdateStep::Sgd { ranges: r } = step {
                r.clone_from(&ranges);
            }
        }
        self.subspace_only = true;
        Ok(trainable)
    }

    pub fn plan(&self) -> &ModelPlan {
        &self.graph.plan
    }

    pub fn graph(&self) -> &LayerGraph {
        &self.graph
    }

    pub fn set_profiling(&mut self, on: bool) {
        self.profiling = on;
    }

    pub fn reset_timings(&mut self) {
        for s in &mut self.slots {
            s.fwd_s = 0.0;
            s.bwd_s = 0.0;
            s.calls = 0;
        }
    }

    pub fn node_timings(&self) -> Vec<NodeTiming> {
        self.slots
            .iter()
            .map(|s| NodeTiming {
                label: s.label.clone(),
                out_features: s.out_features,
                fwd_s: s.fwd_s,
                bwd_s: s.bwd_s,
                calls: s.calls,
            })
            .collect()
    }

    fn check_params(&self, params: &[f32]) -> Result<()> {
        if params.len() != self.params_len {
            bail!("params length {} != manifest {}", params.len(), self.params_len);
        }
        Ok(())
    }

    /// Training forward: runs the node program, saving what each node's
    /// backward dual needs.  Returns the logits (batch × classes).
    pub fn forward_train(&mut self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        self.check_params(params)?;
        let b = self.batch;
        if x.len() != b * self.input_dim {
            bail!("x length {} != batch {} * input_dim {}", x.len(), b, self.input_dim);
        }
        // Planned (arena) walk unless profiling wants per-node timers —
        // the original per-Vec path keeps the latency attribution.
        if !self.profiling && self.train_prog().is_some() {
            return self.forward_train_planned(params, x);
        }
        self.fwd_was_planned = false;
        let (t, d) = (self.graph.plan.tokens, self.graph.plan.dim);
        let (image, patch) = (self.graph.plan.image, self.graph.plan.patch);
        let profiling = self.profiling;
        let mut cur: Vec<f32> = Vec::new();
        let mut stack: Vec<Vec<f32>> = Vec::new();
        for si in 0..self.slots.len() {
            let t0 = profiling.then(Instant::now);
            let slot = &mut self.slots[si];
            match &slot.bind {
                Bind::Patchify => {
                    cur = ops::patchify(x, b, image, patch);
                }
                Bind::Dense { w, b: bs, o, i, .. } => {
                    let rows = cur.len() / *i;
                    let mut y = vec![0.0f32; rows * *o];
                    kernels::gemm_nt(
                        &cur,
                        &params[w.offset..w.offset + w.numel()],
                        rows,
                        *i,
                        *o,
                        &mut y,
                        Epilogue::Bias(&params[bs.offset..bs.offset + bs.numel()]),
                    );
                    slot.saved = Saved::X(std::mem::take(&mut cur));
                    cur = y;
                }
                Bind::Wasi { l, r, b: bs, o, k, i, .. } => {
                    let rows = cur.len() / *i;
                    let mut h = vec![0.0f32; rows * *k];
                    kernels::gemm_nt(
                        &cur,
                        &params[r.offset..r.offset + r.numel()],
                        rows,
                        *i,
                        *k,
                        &mut h,
                        Epilogue::None,
                    );
                    let mut y = vec![0.0f32; rows * *o];
                    kernels::gemm_nt(
                        &h,
                        &params[l.offset..l.offset + l.numel()],
                        rows,
                        *k,
                        *o,
                        &mut y,
                        Epilogue::Bias(&params[bs.offset..bs.offset + bs.numel()]),
                    );
                    let n_tok = rows / b;
                    let xt = Tensor::from_vec(&[b, n_tok, *i], std::mem::take(&mut cur));
                    let comp = slot
                        .asi
                        .as_mut()
                        .expect("wasi node without ASI compressor")
                        .compress(&xt);
                    slot.saved = Saved::Wasi { comp, h };
                    cur = y;
                }
                Bind::Assemble { cls, pos } => {
                    let clsv = &params[cls.offset..cls.offset + cls.numel()];
                    let posv = &params[pos.offset..pos.offset + pos.numel()];
                    let mut tok = vec![0.0f32; b * t * d];
                    for bi in 0..b {
                        tok[bi * t * d..bi * t * d + d].copy_from_slice(clsv);
                        let src = &cur[bi * (t - 1) * d..(bi + 1) * (t - 1) * d];
                        tok[bi * t * d + d..(bi + 1) * t * d].copy_from_slice(src);
                        for (o, p) in tok[bi * t * d..(bi + 1) * t * d].iter_mut().zip(posv) {
                            *o += p;
                        }
                    }
                    cur = tok;
                }
                Bind::LayerNorm { g, b: bs } => {
                    let gv = &params[g.offset..g.offset + g.numel()];
                    let bv = &params[bs.offset..bs.offset + bs.numel()];
                    let dd = g.numel();
                    let rows = cur.len() / dd;
                    let mut xhat = vec![0.0f32; cur.len()];
                    let mut inv_std = vec![0.0f32; rows];
                    let mut y = vec![0.0f32; cur.len()];
                    for rr in 0..rows {
                        let xi = &cur[rr * dd..(rr + 1) * dd];
                        let mu = xi.iter().sum::<f32>() / dd as f32;
                        let var =
                            xi.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / dd as f32;
                        let is = 1.0 / (var + ops::LN_EPS).sqrt();
                        inv_std[rr] = is;
                        for c in 0..dd {
                            let hh = (xi[c] - mu) * is;
                            xhat[rr * dd + c] = hh;
                            y[rr * dd + c] = hh * gv[c] + bv[c];
                        }
                    }
                    slot.saved = Saved::Ln { xhat, inv_std };
                    cur = y;
                }
                Bind::SliceV => {
                    let rows = cur.len() / (3 * d);
                    let mut v = vec![0.0f32; rows * d];
                    for row in 0..rows {
                        v[row * d..(row + 1) * d]
                            .copy_from_slice(&cur[row * 3 * d + 2 * d..(row + 1) * 3 * d]);
                    }
                    cur = v;
                }
                Bind::Mixing => {
                    ops::uniform_mix(&mut cur, b, t, d);
                }
                Bind::Gelu => {
                    let pre = std::mem::take(&mut cur);
                    cur = pre.iter().map(|&v| kernels::gelu(v)).collect();
                    slot.saved = Saved::Gelu(pre);
                }
                Bind::ResidualSave => {
                    stack.push(cur.clone());
                }
                Bind::ResidualAdd => {
                    let res = stack.pop().ok_or_else(|| anyhow!("residual stack underflow"))?;
                    for (v, a) in cur.iter_mut().zip(&res) {
                        *v += a;
                    }
                }
                Bind::TakeCls => {
                    let mut clstok = vec![0.0f32; b * d];
                    for bi in 0..b {
                        clstok[bi * d..(bi + 1) * d]
                            .copy_from_slice(&cur[bi * t * d..bi * t * d + d]);
                    }
                    cur = clstok;
                }
                Bind::SoftmaxCe => {
                    // Terminal: loss/accuracy/dlogits happen in
                    // `loss_and_grad` (timed onto this node there).
                }
            }
            if let Some(t0) = t0 {
                slot.fwd_s += t0.elapsed().as_secs_f64();
                slot.calls += 1;
            }
        }
        Ok(cur)
    }

    /// [`GraphExecutor::forward_train`]'s arena-planned mirror: the
    /// same kernel calls in the same order on identically-sized
    /// buffers, with every transient `Vec` replaced by a planned arena
    /// range — bit-identical by construction, zero steady-state heap
    /// allocation (the returned logits `Vec` is the one boundary copy).
    fn forward_train_planned(&mut self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch;
        let (t, d) = (self.graph.plan.tokens, self.graph.plan.dim);
        let (image, patch) = (self.graph.plan.image, self.graph.plan.patch);
        let (arena_elems, out_r) = {
            let tp = self.train_prog().expect("planned forward without a train program");
            (tp.arena_elems, tp.out)
        };
        let mut arena = std::mem::take(&mut self.train_arena);
        if arena.len() != arena_elems {
            arena.resize(arena_elems, 0.0);
        }
        let ap = arena.as_mut_ptr();
        for si in 0..self.slots.len() {
            let sb = self.train_prog().expect("checked above").steps[si];
            let slot = &mut self.slots[si];
            match &slot.bind {
                Bind::Patchify => {
                    let out = unsafe { aw(ap, sb.out) };
                    ops::patchify_into(x, b, image, patch, out);
                }
                Bind::Dense { w, b: bs, o, i, .. } => {
                    let (y, xs) = unsafe { (aw(ap, sb.out), ar(ap, sb.src)) };
                    let rows = xs.len() / *i;
                    kernels::gemm_nt(
                        xs,
                        &params[w.offset..w.offset + w.numel()],
                        rows,
                        *i,
                        *o,
                        y,
                        Epilogue::Bias(&params[bs.offset..bs.offset + bs.numel()]),
                    );
                }
                Bind::Wasi { l, r, b: bs, o, k, i, .. } => {
                    {
                        let (h, xs) = unsafe { (aw(ap, sb.a), ar(ap, sb.src)) };
                        let rows = xs.len() / *i;
                        kernels::gemm_nt(
                            xs,
                            &params[r.offset..r.offset + r.numel()],
                            rows,
                            *i,
                            *k,
                            h,
                            Epilogue::None,
                        );
                    }
                    {
                        let (y, h) = unsafe { (aw(ap, sb.out), ar(ap, sb.a)) };
                        let rows = h.len() / *k;
                        kernels::gemm_nt(
                            h,
                            &params[l.offset..l.offset + l.numel()],
                            rows,
                            *k,
                            *o,
                            y,
                            Epilogue::Bias(&params[bs.offset..bs.offset + bs.numel()]),
                        );
                    }
                    // ASI compresses a tensor-shaped copy of the input;
                    // the scratch vector's capacity is reclaimed from
                    // the consumed Tensor every step.
                    let xs = unsafe { ar(ap, sb.src) };
                    let rows = xs.len() / *i;
                    let n_tok = rows / b;
                    let mut scratch = std::mem::take(&mut self.scratch_x);
                    scratch.clear();
                    scratch.extend_from_slice(xs);
                    let xt = Tensor::from_vec(&[b, n_tok, *i], scratch);
                    let comp = slot
                        .asi
                        .as_mut()
                        .expect("wasi node without ASI compressor")
                        .compress(&xt);
                    slot.saved = Saved::Wasi { comp, h: Vec::new() };
                    self.scratch_x = xt.data;
                }
                Bind::Assemble { cls, pos } => {
                    let clsv = &params[cls.offset..cls.offset + cls.numel()];
                    let posv = &params[pos.offset..pos.offset + pos.numel()];
                    let (tok, src) = unsafe { (aw(ap, sb.out), ar(ap, sb.src)) };
                    for bi in 0..b {
                        tok[bi * t * d..bi * t * d + d].copy_from_slice(clsv);
                        let srow = &src[bi * (t - 1) * d..(bi + 1) * (t - 1) * d];
                        tok[bi * t * d + d..(bi + 1) * t * d].copy_from_slice(srow);
                        for (o, p) in tok[bi * t * d..(bi + 1) * t * d].iter_mut().zip(posv) {
                            *o += p;
                        }
                    }
                }
                Bind::LayerNorm { g, b: bs } => {
                    let gv = &params[g.offset..g.offset + g.numel()];
                    let bv = &params[bs.offset..bs.offset + bs.numel()];
                    let dd = g.numel();
                    let (y, src) = unsafe { (aw(ap, sb.out), ar(ap, sb.src)) };
                    let (xhat, inv_std) = unsafe { (aw(ap, sb.a), aw(ap, sb.b)) };
                    let rows = src.len() / dd;
                    for rr in 0..rows {
                        let xi = &src[rr * dd..(rr + 1) * dd];
                        let mu = xi.iter().sum::<f32>() / dd as f32;
                        let var =
                            xi.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / dd as f32;
                        let is = 1.0 / (var + ops::LN_EPS).sqrt();
                        inv_std[rr] = is;
                        for c in 0..dd {
                            let hh = (xi[c] - mu) * is;
                            xhat[rr * dd + c] = hh;
                            y[rr * dd + c] = hh * gv[c] + bv[c];
                        }
                    }
                }
                Bind::SliceV => {
                    let (v, src) = unsafe { (aw(ap, sb.out), ar(ap, sb.src)) };
                    let rows = src.len() / (3 * d);
                    for row in 0..rows {
                        v[row * d..(row + 1) * d]
                            .copy_from_slice(&src[row * 3 * d + 2 * d..(row + 1) * 3 * d]);
                    }
                }
                Bind::Mixing => {
                    let cur = unsafe { aw(ap, sb.out) };
                    ops::uniform_mix_scratch(cur, b, t, d, &mut self.scratch_mean);
                }
                Bind::Gelu => {
                    let (y, pre) = unsafe { (aw(ap, sb.out), ar(ap, sb.src)) };
                    for (o, &v) in y.iter_mut().zip(pre) {
                        *o = kernels::gelu(v);
                    }
                }
                Bind::ResidualSave => {
                    let (cpy, src) = unsafe { (aw(ap, sb.a), ar(ap, sb.src)) };
                    cpy.copy_from_slice(src);
                }
                Bind::ResidualAdd => {
                    let (cur, res) = unsafe { (aw(ap, sb.out), ar(ap, sb.a)) };
                    for (v, a) in cur.iter_mut().zip(res) {
                        *v += a;
                    }
                }
                Bind::TakeCls => {
                    let (cl, src) = unsafe { (aw(ap, sb.out), ar(ap, sb.src)) };
                    for bi in 0..b {
                        cl[bi * d..(bi + 1) * d]
                            .copy_from_slice(&src[bi * t * d..bi * t * d + d]);
                    }
                }
                Bind::SoftmaxCe => {}
            }
        }
        let logits = unsafe { ar(ap, out_r) }.to_vec();
        self.train_arena = arena;
        self.fwd_was_planned = true;
        Ok(logits)
    }

    /// Softmax cross-entropy head: loss, accuracy, dlogits.
    pub fn loss_and_grad(&mut self, logits: &[f32], y_onehot: &[f32]) -> (f32, f32, Vec<f32>) {
        let t0 = self.profiling.then(Instant::now);
        let c = self.graph.plan.classes;
        let b = self.batch;
        let logp = ops::log_softmax_rows(logits, c);
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut dl = vec![0.0f32; logits.len()];
        for row in 0..b {
            let lp = &logp[row * c..(row + 1) * c];
            let y = &y_onehot[row * c..(row + 1) * c];
            let mut row_loss = 0.0f32;
            let mut label = 0usize;
            for j in 0..c {
                row_loss -= y[j] * lp[j];
                if y[j] > y[label] {
                    label = j;
                }
            }
            loss += row_loss as f64;
            let pred = (0..c)
                .max_by(|&a, &bb| lp[a].total_cmp(&lp[bb]))
                .unwrap_or(0);
            if pred == label {
                correct += 1;
            }
            for j in 0..c {
                dl[row * c + j] = (lp[j].exp() - y[j]) / b as f32;
            }
        }
        if let Some(t0) = t0 {
            // fwd_s only: forward_train already counted this node's call.
            if let Some(last) = self.slots.last_mut() {
                last.fwd_s += t0.elapsed().as_secs_f64();
            }
        }
        (
            (loss / b as f64) as f32,
            correct as f32 / b as f32,
            dl,
        )
    }

    /// Backward: runs the node program in reverse, writing the flat
    /// gradient vector (caller passes it zeroed).
    pub fn backward(&mut self, params: &[f32], dlogits: &[f32], grads: &mut [f32]) -> Result<()> {
        self.check_params(params)?;
        if grads.len() != self.params_len {
            bail!("grads length {} != manifest {}", grads.len(), self.params_len);
        }
        if self.fwd_was_planned {
            // The planned forward saved its activations in the arena;
            // only the planned backward knows how to read them.
            return self.backward_planned(params, dlogits, grads);
        }
        let b = self.batch;
        let (t, d) = (self.graph.plan.tokens, self.graph.plan.dim);
        let profiling = self.profiling;
        let mut dcur = dlogits.to_vec();
        let mut dstack: Vec<Vec<f32>> = Vec::new();
        for si in (0..self.slots.len()).rev() {
            let t0 = profiling.then(Instant::now);
            let slot = &mut self.slots[si];
            match &slot.bind {
                Bind::SoftmaxCe => {}
                Bind::Dense { w, b: bs, o, i, needs_dx } => {
                    let Saved::X(xsave) = std::mem::replace(&mut slot.saved, Saved::None)
                    else {
                        bail!("dense backward without a forward ({})", slot.label);
                    };
                    let rows = dcur.len() / *o;
                    {
                        let db = &mut grads[bs.offset..bs.offset + bs.numel()];
                        for chunk in dcur.chunks(*o) {
                            for (g, v) in db.iter_mut().zip(chunk) {
                                *g += v;
                            }
                        }
                    }
                    // dW = dYᵀ·X GEMM'd straight into the flat grad
                    // vector — no per-layer dW allocation.
                    kernels::gemm_tn(
                        &dcur,
                        &xsave,
                        *o,
                        rows,
                        *i,
                        &mut grads[w.offset..w.offset + w.numel()],
                        Epilogue::None,
                    );
                    if *needs_dx {
                        let mut dx = vec![0.0f32; rows * *i];
                        kernels::gemm_nn(
                            &dcur,
                            &params[w.offset..w.offset + w.numel()],
                            rows,
                            *o,
                            *i,
                            &mut dx,
                            Epilogue::None,
                        );
                        dcur = dx;
                    } else {
                        dcur = Vec::new();
                    }
                }
                Bind::Wasi { l, r, b: bs, o, k, i, .. } => {
                    let Saved::Wasi { comp, h } = std::mem::replace(&mut slot.saved, Saved::None)
                    else {
                        bail!("wasi backward without a forward ({})", slot.label);
                    };
                    let rows = dcur.len() / *o;
                    {
                        let db = &mut grads[bs.offset..bs.offset + bs.numel()];
                        for chunk in dcur.chunks(*o) {
                            for (g, v) in db.iter_mut().zip(chunk) {
                                *g += v;
                            }
                        }
                    }
                    // Eq. 10: dH = dY L (rank space), dX = dH R.
                    let mut dh = vec![0.0f32; rows * *k];
                    kernels::gemm_nn(
                        &dcur,
                        &params[l.offset..l.offset + l.numel()],
                        rows,
                        *o,
                        *k,
                        &mut dh,
                        Epilogue::None,
                    );
                    // dL = dYᵀ·H straight into the flat grad vector.
                    kernels::gemm_tn(
                        &dcur,
                        &h,
                        *o,
                        rows,
                        *k,
                        &mut grads[l.offset..l.offset + l.numel()],
                        Epilogue::None,
                    );
                    let mut dx = vec![0.0f32; rows * *i];
                    kernels::gemm_nn(
                        &dh,
                        &params[r.offset..r.offset + r.numel()],
                        rows,
                        *k,
                        *i,
                        &mut dx,
                        Epilogue::None,
                    );
                    // dR via f_LR with dH in place of dY (DESIGN.md §2.2).
                    let n_tok = rows / b;
                    let dh_t = Tensor::from_vec(&[b, n_tok, *k], dh);
                    let dr = lowrank_grad_3d(
                        &comp.core,
                        &comp.factors[0],
                        &comp.factors[1],
                        &comp.factors[2],
                        &dh_t,
                    );
                    grads[r.offset..r.offset + r.numel()].copy_from_slice(&dr.data);
                    dcur = dx;
                }
                Bind::LayerNorm { g, b: bs } => {
                    let Saved::Ln { xhat, inv_std } =
                        std::mem::replace(&mut slot.saved, Saved::None)
                    else {
                        bail!("layer-norm backward without a forward ({})", slot.label);
                    };
                    let gv = &params[g.offset..g.offset + g.numel()];
                    let dd = g.numel();
                    let rows = dcur.len() / dd;
                    let mut dg = vec![0.0f32; dd];
                    let mut db = vec![0.0f32; dd];
                    let mut dx = vec![0.0f32; dcur.len()];
                    for rr in 0..rows {
                        let dyr = &dcur[rr * dd..(rr + 1) * dd];
                        let xhr = &xhat[rr * dd..(rr + 1) * dd];
                        let mut m1 = 0.0f32; // mean(dxhat)
                        let mut m2 = 0.0f32; // mean(dxhat * xhat)
                        for c in 0..dd {
                            let dxh = dyr[c] * gv[c];
                            m1 += dxh;
                            m2 += dxh * xhr[c];
                            dg[c] += dyr[c] * xhr[c];
                            db[c] += dyr[c];
                        }
                        m1 /= dd as f32;
                        m2 /= dd as f32;
                        for c in 0..dd {
                            let dxh = dyr[c] * gv[c];
                            dx[rr * dd + c] = inv_std[rr] * (dxh - m1 - xhr[c] * m2);
                        }
                    }
                    for (gs, v) in grads[g.offset..g.offset + dd].iter_mut().zip(&dg) {
                        *gs += v;
                    }
                    for (gs, v) in grads[bs.offset..bs.offset + dd].iter_mut().zip(&db) {
                        *gs += v;
                    }
                    dcur = dx;
                }
                Bind::Gelu => {
                    let Saved::Gelu(pre) = std::mem::replace(&mut slot.saved, Saved::None)
                    else {
                        bail!("gelu backward without a forward");
                    };
                    for (dv, &pv) in dcur.iter_mut().zip(&pre) {
                        *dv *= kernels::gelu_grad(pv);
                    }
                }
                Bind::SliceV => {
                    let rows = dcur.len() / d;
                    let mut da = vec![0.0f32; rows * 3 * d];
                    for row in 0..rows {
                        da[row * 3 * d + 2 * d..(row + 1) * 3 * d]
                            .copy_from_slice(&dcur[row * d..(row + 1) * d]);
                    }
                    dcur = da;
                }
                Bind::Mixing => {
                    // (I + 11ᵀ/T)/2 is symmetric: backward is the same
                    // operator.
                    ops::uniform_mix(&mut dcur, b, t, d);
                }
                Bind::ResidualAdd => {
                    dstack.push(dcur.clone());
                }
                Bind::ResidualSave => {
                    let dres = dstack.pop().ok_or_else(|| anyhow!("residual dstack underflow"))?;
                    for (v, a) in dcur.iter_mut().zip(&dres) {
                        *v += a;
                    }
                }
                Bind::TakeCls => {
                    let mut dz = vec![0.0f32; b * t * d];
                    for bi in 0..b {
                        dz[bi * t * d..bi * t * d + d]
                            .copy_from_slice(&dcur[bi * d..(bi + 1) * d]);
                    }
                    dcur = dz;
                }
                Bind::Assemble { cls, pos } => {
                    {
                        let dpos = &mut grads[pos.offset..pos.offset + pos.numel()];
                        for bi in 0..b {
                            for (g, v) in
                                dpos.iter_mut().zip(&dcur[bi * t * d..(bi + 1) * t * d])
                            {
                                *g += v;
                            }
                        }
                    }
                    {
                        let dcls = &mut grads[cls.offset..cls.offset + cls.numel()];
                        for bi in 0..b {
                            for (g, v) in
                                dcls.iter_mut().zip(&dcur[bi * t * d..bi * t * d + d])
                            {
                                *g += v;
                            }
                        }
                    }
                    let mut demb = vec![0.0f32; b * (t - 1) * d];
                    for bi in 0..b {
                        demb[bi * (t - 1) * d..(bi + 1) * (t - 1) * d]
                            .copy_from_slice(&dcur[bi * t * d + d..(bi + 1) * t * d]);
                    }
                    dcur = demb;
                }
                Bind::Patchify => {
                    // Input gradients are never needed.
                    dcur = Vec::new();
                }
            }
            if let Some(t0) = t0 {
                slot.bwd_s += t0.elapsed().as_secs_f64();
            }
        }
        Ok(())
    }

    /// [`GraphExecutor::backward`]'s arena-planned mirror: reverse walk
    /// over the same kernels in the same order, reading saved
    /// activations straight out of the forward's arena ranges instead
    /// of per-slot `Saved` vectors.
    fn backward_planned(
        &mut self,
        params: &[f32],
        dlogits: &[f32],
        grads: &mut [f32],
    ) -> Result<()> {
        let b = self.batch;
        let (t, d) = (self.graph.plan.tokens, self.graph.plan.dim);
        let (arena_elems, dl0) = {
            let tp = self.train_prog().expect("planned backward without a train program");
            (tp.arena_elems, tp.dl0)
        };
        if dlogits.len() != dl0.len {
            bail!("dlogits length {} != planned {}", dlogits.len(), dl0.len);
        }
        let mut arena = std::mem::take(&mut self.train_arena);
        if arena.len() != arena_elems {
            arena.resize(arena_elems, 0.0);
        }
        let ap = arena.as_mut_ptr();
        unsafe { aw(ap, dl0) }.copy_from_slice(dlogits);
        for si in (0..self.slots.len()).rev() {
            let (sb, fb) = {
                let tp = self.train_prog().expect("checked above");
                (tp.bwd[si], tp.steps[si])
            };
            let slot = &mut self.slots[si];
            match &slot.bind {
                Bind::SoftmaxCe => {}
                Bind::Dense { w, b: bs, o, i, needs_dx } => {
                    let (dcur, xsave) = unsafe { (ar(ap, sb.src), ar(ap, fb.src)) };
                    let rows = dcur.len() / *o;
                    {
                        let db = &mut grads[bs.offset..bs.offset + bs.numel()];
                        for chunk in dcur.chunks(*o) {
                            for (g, v) in db.iter_mut().zip(chunk) {
                                *g += v;
                            }
                        }
                    }
                    kernels::gemm_tn(
                        dcur,
                        xsave,
                        *o,
                        rows,
                        *i,
                        &mut grads[w.offset..w.offset + w.numel()],
                        Epilogue::None,
                    );
                    if *needs_dx {
                        let dx = unsafe { aw(ap, sb.out) };
                        kernels::gemm_nn(
                            dcur,
                            &params[w.offset..w.offset + w.numel()],
                            rows,
                            *o,
                            *i,
                            dx,
                            Epilogue::None,
                        );
                    }
                }
                Bind::Wasi { l, r, b: bs, o, k, i, .. } => {
                    let Saved::Wasi { comp, .. } =
                        std::mem::replace(&mut slot.saved, Saved::None)
                    else {
                        bail!("wasi backward without a forward ({})", slot.label);
                    };
                    let dcur = unsafe { ar(ap, sb.src) };
                    let rows = dcur.len() / *o;
                    {
                        let db = &mut grads[bs.offset..bs.offset + bs.numel()];
                        for chunk in dcur.chunks(*o) {
                            for (g, v) in db.iter_mut().zip(chunk) {
                                *g += v;
                            }
                        }
                    }
                    // Eq. 10: dH = dY L (rank space), dX = dH R.
                    {
                        let dh = unsafe { aw(ap, sb.a) };
                        kernels::gemm_nn(
                            dcur,
                            &params[l.offset..l.offset + l.numel()],
                            rows,
                            *o,
                            *k,
                            dh,
                            Epilogue::None,
                        );
                    }
                    // dL = dYᵀ·H straight into the flat grad vector; H
                    // is the forward's arena range.
                    let h = unsafe { ar(ap, fb.a) };
                    kernels::gemm_tn(
                        dcur,
                        h,
                        *o,
                        rows,
                        *k,
                        &mut grads[l.offset..l.offset + l.numel()],
                        Epilogue::None,
                    );
                    {
                        let (dx, dh) = unsafe { (aw(ap, sb.out), ar(ap, sb.a)) };
                        kernels::gemm_nn(
                            dh,
                            &params[r.offset..r.offset + r.numel()],
                            rows,
                            *k,
                            *i,
                            dx,
                            Epilogue::None,
                        );
                    }
                    // dR via f_LR with dH in place of dY (DESIGN.md
                    // §2.2); the scratch vector round-trips through the
                    // Tensor exactly like the forward's ASI copy.
                    let n_tok = rows / b;
                    let mut scratch = std::mem::take(&mut self.scratch_dh);
                    scratch.clear();
                    scratch.extend_from_slice(unsafe { ar(ap, sb.a) });
                    let dh_t = Tensor::from_vec(&[b, n_tok, *k], scratch);
                    let dr = lowrank_grad_3d(
                        &comp.core,
                        &comp.factors[0],
                        &comp.factors[1],
                        &comp.factors[2],
                        &dh_t,
                    );
                    grads[r.offset..r.offset + r.numel()].copy_from_slice(&dr.data);
                    self.scratch_dh = dh_t.data;
                }
                Bind::LayerNorm { g, b: bs } => {
                    let gv = &params[g.offset..g.offset + g.numel()];
                    let dd = g.numel();
                    let (dcur, xhat) = unsafe { (ar(ap, sb.src), ar(ap, fb.a)) };
                    let inv_std = unsafe { ar(ap, fb.b) };
                    let rows = dcur.len() / dd;
                    let (dx, dg) = unsafe { (aw(ap, sb.out), aw(ap, sb.a)) };
                    let db = unsafe { aw(ap, sb.b) };
                    dg.fill(0.0);
                    db.fill(0.0);
                    for rr in 0..rows {
                        let dyr = &dcur[rr * dd..(rr + 1) * dd];
                        let xhr = &xhat[rr * dd..(rr + 1) * dd];
                        let mut m1 = 0.0f32; // mean(dxhat)
                        let mut m2 = 0.0f32; // mean(dxhat * xhat)
                        for c in 0..dd {
                            let dxh = dyr[c] * gv[c];
                            m1 += dxh;
                            m2 += dxh * xhr[c];
                            dg[c] += dyr[c] * xhr[c];
                            db[c] += dyr[c];
                        }
                        m1 /= dd as f32;
                        m2 /= dd as f32;
                        for c in 0..dd {
                            let dxh = dyr[c] * gv[c];
                            dx[rr * dd + c] = inv_std[rr] * (dxh - m1 - xhr[c] * m2);
                        }
                    }
                    for (gs, v) in grads[g.offset..g.offset + dd].iter_mut().zip(&*dg) {
                        *gs += v;
                    }
                    for (gs, v) in grads[bs.offset..bs.offset + dd].iter_mut().zip(&*db) {
                        *gs += v;
                    }
                }
                Bind::Gelu => {
                    let (dcur, pre) = unsafe { (aw(ap, sb.out), ar(ap, fb.src)) };
                    for (dv, &pv) in dcur.iter_mut().zip(pre) {
                        *dv *= kernels::gelu_grad(pv);
                    }
                }
                Bind::SliceV => {
                    let (da, dcur) = unsafe { (aw(ap, sb.out), ar(ap, sb.src)) };
                    da.fill(0.0);
                    let rows = dcur.len() / d;
                    for row in 0..rows {
                        da[row * 3 * d + 2 * d..(row + 1) * 3 * d]
                            .copy_from_slice(&dcur[row * d..(row + 1) * d]);
                    }
                }
                Bind::Mixing => {
                    // (I + 11ᵀ/T)/2 is symmetric: backward is the same
                    // operator.
                    let dcur = unsafe { aw(ap, sb.out) };
                    ops::uniform_mix_scratch(dcur, b, t, d, &mut self.scratch_mean);
                }
                Bind::ResidualAdd => {
                    let (cpy, dcur) = unsafe { (aw(ap, sb.a), ar(ap, sb.src)) };
                    cpy.copy_from_slice(dcur);
                }
                Bind::ResidualSave => {
                    let (cur, res) = unsafe { (aw(ap, sb.out), ar(ap, sb.a)) };
                    for (v, a) in cur.iter_mut().zip(res) {
                        *v += a;
                    }
                }
                Bind::TakeCls => {
                    let (dz, dcur) = unsafe { (aw(ap, sb.out), ar(ap, sb.src)) };
                    dz.fill(0.0);
                    for bi in 0..b {
                        dz[bi * t * d..bi * t * d + d]
                            .copy_from_slice(&dcur[bi * d..(bi + 1) * d]);
                    }
                }
                Bind::Assemble { cls, pos } => {
                    let dcur = unsafe { ar(ap, sb.src) };
                    {
                        let dpos = &mut grads[pos.offset..pos.offset + pos.numel()];
                        for bi in 0..b {
                            for (g, v) in
                                dpos.iter_mut().zip(&dcur[bi * t * d..(bi + 1) * t * d])
                            {
                                *g += v;
                            }
                        }
                    }
                    {
                        let dcls = &mut grads[cls.offset..cls.offset + cls.numel()];
                        for bi in 0..b {
                            for (g, v) in
                                dcls.iter_mut().zip(&dcur[bi * t * d..bi * t * d + d])
                            {
                                *g += v;
                            }
                        }
                    }
                    let demb = unsafe { aw(ap, sb.out) };
                    for bi in 0..b {
                        demb[bi * (t - 1) * d..(bi + 1) * (t - 1) * d]
                            .copy_from_slice(&dcur[bi * t * d + d..(bi + 1) * t * d]);
                    }
                }
                Bind::Patchify => {
                    // Input gradients are never needed.
                }
            }
        }
        self.train_arena = arena;
        self.fwd_was_planned = false;
        Ok(())
    }

    /// Run the optimizer program: global-norm clip + decoupled weight
    /// decay + SGD, then the per-layer WSI refreshes — all in flat
    /// parameter space (mirrors the AOT step's update rule).
    pub fn update(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        let norm = if self.subspace_only {
            // Subspace-only training: frozen tensors receive no update,
            // so their gradients must not dilute the clip norm — the
            // trainable ranges are the whole parameter set as far as
            // the optimizer is concerned.
            let mut acc = 0.0f64;
            for step in &self.updates {
                if let UpdateStep::Sgd { ranges } = step {
                    for &(lo, hi, _) in ranges {
                        acc += grads[lo..hi]
                            .iter()
                            .map(|g| (*g as f64) * (*g as f64))
                            .sum::<f64>();
                    }
                }
            }
            acc.sqrt() as f32
        } else {
            grads
                .iter()
                .map(|g| (*g as f64) * (*g as f64))
                .sum::<f64>()
                .sqrt() as f32
        };
        let scale = if norm > GRAD_CLIP { GRAD_CLIP / norm } else { 1.0 };
        for step in &self.updates {
            match step {
                UpdateStep::Sgd { ranges } => {
                    for &(lo, hi, wd) in ranges {
                        for (p, g) in params[lo..hi].iter_mut().zip(&grads[lo..hi]) {
                            *p -= lr * (g * scale + wd * *p);
                        }
                    }
                }
                UpdateStep::Refresh { l, r, o, k, i } => {
                    let mut f = WsiFactors {
                        l: Mat::from_vec(
                            *o,
                            *k,
                            params[l.offset..l.offset + l.numel()].to_vec(),
                        ),
                        r: Mat::from_vec(
                            *k,
                            *i,
                            params[r.offset..r.offset + r.numel()].to_vec(),
                        ),
                    };
                    f.refresh();
                    params[l.offset..l.offset + l.numel()].copy_from_slice(&f.l.data);
                    params[r.offset..r.offset + r.numel()].copy_from_slice(&f.r.data);
                }
            }
        }
    }

    /// Copy ASI warm-start bases out of the flat state vector into the
    /// node compressors (checkpoint restore / construction).
    pub fn load_state(&mut self, state: &[f32]) -> Result<()> {
        if state.len() != self.state_len {
            bail!("state length {} != manifest {}", state.len(), self.state_len);
        }
        if self.state_spec.is_empty() {
            return Ok(());
        }
        let specs: BTreeMap<&str, &TensorSpec> =
            self.state_spec.iter().map(|t| (t.name.as_str(), t)).collect();
        for slot in &mut self.slots {
            let Bind::Wasi { name, .. } = &slot.bind else { continue };
            let Some(asi) = slot.asi.as_mut() else { continue };
            for (m, st) in asi.states.iter_mut().enumerate() {
                let key = format!("{}.u{}", name, m + 1);
                if let Some(spec) = specs.get(key.as_str()) {
                    // Shipped warm-start bases must fit exactly; silently
                    // training from random init instead would be the
                    // quiet-garbage failure mode this engine refuses on
                    // principle.
                    if spec.shape != [st.u.rows, st.u.cols] {
                        bail!(
                            "state tensor {key} shape {:?} does not match the \
                             ASI basis ({}, {})",
                            spec.shape,
                            st.u.rows,
                            st.u.cols
                        );
                    }
                    if spec.offset + spec.numel() > state.len() {
                        bail!(
                            "state tensor {key} [{:?} @ {}] overruns state_len {}",
                            spec.shape,
                            spec.offset,
                            state.len()
                        );
                    }
                    st.u.data
                        .copy_from_slice(&state[spec.offset..spec.offset + spec.numel()]);
                }
            }
        }
        Ok(())
    }

    /// Pack the (forward-refreshed) ASI bases back into the flat state
    /// vector.  State entries that belong to layers the graph keeps
    /// dense pass through unchanged.
    pub fn store_state(&self, state: &mut [f32]) {
        if self.state_spec.is_empty() {
            return;
        }
        let specs: BTreeMap<&str, &TensorSpec> =
            self.state_spec.iter().map(|t| (t.name.as_str(), t)).collect();
        for slot in &self.slots {
            let Bind::Wasi { name, .. } = &slot.bind else { continue };
            let Some(asi) = slot.asi.as_ref() else { continue };
            for (m, st) in asi.states.iter().enumerate() {
                let key = format!("{}.u{}", name, m + 1);
                if let Some(spec) = specs.get(key.as_str()) {
                    if spec.numel() == st.u.data.len()
                        && spec.offset + spec.numel() <= state.len()
                    {
                        state[spec.offset..spec.offset + spec.numel()]
                            .copy_from_slice(&st.u.data);
                    }
                }
            }
        }
    }

    /// Inference walk: batch-size free, saves nothing, and fuses a
    /// following GELU into the producing linear's epilogue.
    pub fn infer(&self, params: &[f32], x: &[f32], b: usize) -> Result<Vec<f32>> {
        self.infer_view(ParamsView::Flat(params), x, b)
    }

    /// [`GraphExecutor::infer`] against a packed reduced-precision
    /// parameter set (DESIGN.md §Precision): GEMM weights dequantize in
    /// the kernel's inner loop / epilogue, everything else reads f32.
    pub fn infer_packed(&self, packed: &PackedParams, x: &[f32], b: usize) -> Result<Vec<f32>> {
        self.infer_view(ParamsView::Packed(packed), x, b)
    }

    /// [`GraphExecutor::infer`] with a variant's subspace factors
    /// overlaid on the shared frozen base (delta-apply serving,
    /// DESIGN.md §Variant store).  Bit-identical to the same call on
    /// the materialized vector: both feed the same f32 values through
    /// the same kernel walk.
    pub fn infer_overlay(&self, overlay: &DeltaOverlay, x: &[f32], b: usize) -> Result<Vec<f32>> {
        self.infer_view(ParamsView::Overlay(overlay), x, b)
    }

    fn infer_view(&self, params: ParamsView, x: &[f32], b: usize) -> Result<Vec<f32>> {
        if params.len() != self.params_len {
            bail!("params length {} != manifest {}", params.len(), self.params_len);
        }
        if b == 0 || x.len() != b * self.input_dim {
            bail!(
                "x length {} is not a positive multiple of input_dim {}",
                x.len(),
                self.input_dim
            );
        }
        if self.opt.is_some() {
            return self.infer_view_planned(params, x, b);
        }
        let (t, d) = (self.graph.plan.tokens, self.graph.plan.dim);
        let (image, patch) = (self.graph.plan.image, self.graph.plan.patch);
        let folded = self.folded_const(params);
        let mut cur: Vec<f32> = Vec::new();
        let mut stack: Vec<Vec<f32>> = Vec::new();
        let mut si = 0;
        while si < self.slots.len() {
            let slot = &self.slots[si];
            let fuse_gelu = self.passes.fuse()
                && matches!(slot.bind, Bind::Dense { .. } | Bind::Wasi { .. })
                && matches!(self.slots.get(si + 1).map(|s| &s.bind), Some(Bind::Gelu));
            match &slot.bind {
                Bind::Patchify => {
                    cur = ops::patchify(x, b, image, patch);
                }
                Bind::Dense { w, b: bs, o, i, .. } => {
                    let rows = cur.len() / *i;
                    let bias = params.floats(bs)?;
                    let mut y = vec![0.0f32; rows * *o];
                    linear_nt(params.weight(w)?, &cur, rows, *i, *o, Some(bias), fuse_gelu, &mut y);
                    cur = y;
                }
                Bind::Wasi { l, r, b: bs, o, k, i, .. } => {
                    let rows = cur.len() / *i;
                    let mut h = vec![0.0f32; rows * *k];
                    linear_nt(params.weight(r)?, &cur, rows, *i, *k, None, false, &mut h);
                    let bias = params.floats(bs)?;
                    let mut y = vec![0.0f32; rows * *o];
                    linear_nt(params.weight(l)?, &h, rows, *k, *o, Some(bias), fuse_gelu, &mut y);
                    cur = y;
                }
                Bind::Assemble { cls, pos } => {
                    let mut tok = vec![0.0f32; b * t * d];
                    if let Some(fv) = folded {
                        // Folded cls+pos constant (`fold` pass): row 0
                        // is precomputed with the identical single add,
                        // rows 1.. add pos verbatim — bitwise the same.
                        for bi in 0..b {
                            tok[bi * t * d..bi * t * d + d].copy_from_slice(&fv[..d]);
                            let src = &cur[bi * (t - 1) * d..(bi + 1) * (t - 1) * d];
                            tok[bi * t * d + d..(bi + 1) * t * d].copy_from_slice(src);
                            for (o, p) in
                                tok[bi * t * d + d..(bi + 1) * t * d].iter_mut().zip(&fv[d..])
                            {
                                *o += p;
                            }
                        }
                    } else {
                        let clsv = params.floats(cls)?;
                        let posv = params.floats(pos)?;
                        for bi in 0..b {
                            tok[bi * t * d..bi * t * d + d].copy_from_slice(clsv);
                            let src = &cur[bi * (t - 1) * d..(bi + 1) * (t - 1) * d];
                            tok[bi * t * d + d..(bi + 1) * t * d].copy_from_slice(src);
                            for (o, p) in tok[bi * t * d..(bi + 1) * t * d].iter_mut().zip(posv) {
                                *o += p;
                            }
                        }
                    }
                    cur = tok;
                }
                Bind::LayerNorm { g, b: bs } => {
                    let gv = params.floats(g)?;
                    let bv = params.floats(bs)?;
                    ops::layer_norm_inplace(&mut cur, gv, bv, g.numel());
                }
                Bind::SliceV => {
                    let rows = cur.len() / (3 * d);
                    let mut v = vec![0.0f32; rows * d];
                    for row in 0..rows {
                        v[row * d..(row + 1) * d]
                            .copy_from_slice(&cur[row * 3 * d + 2 * d..(row + 1) * 3 * d]);
                    }
                    cur = v;
                }
                Bind::Mixing => {
                    ops::uniform_mix(&mut cur, b, t, d);
                }
                Bind::Gelu => {
                    // Only reached when not fused into the linear above.
                    for v in cur.iter_mut() {
                        *v = kernels::gelu(*v);
                    }
                }
                Bind::ResidualSave => {
                    stack.push(cur.clone());
                }
                Bind::ResidualAdd => {
                    let res = stack.pop().ok_or_else(|| anyhow!("residual stack underflow"))?;
                    for (v, a) in cur.iter_mut().zip(&res) {
                        *v += a;
                    }
                }
                Bind::TakeCls => {
                    let mut clstok = vec![0.0f32; b * d];
                    for bi in 0..b {
                        clstok[bi * d..(bi + 1) * d]
                            .copy_from_slice(&cur[bi * t * d..bi * t * d + d]);
                    }
                    cur = clstok;
                }
                Bind::SoftmaxCe => break,
            }
            si += if fuse_gelu { 2 } else { 1 };
        }
        Ok(cur)
    }

    /// The `fold` pass's precomputed cls+pos constant, when this
    /// executor folds and the parameter source carries one.
    fn folded_const<'a>(&self, params: ParamsView<'a>) -> Option<&'a [f32]> {
        if !self.passes.fold() {
            return None;
        }
        match params {
            ParamsView::Packed(p) => p.assemble_const.as_deref(),
            _ => None,
        }
    }

    /// [`GraphExecutor::infer_view`]'s arena-planned mirror.  The plan
    /// is per batch element; every range is scaled by the call's `b`
    /// (scaling preserves disjointness).  The walk is `&self` on
    /// pool-shared engines, so the arena is thread-local rather than
    /// executor-owned.
    fn infer_view_planned(&self, params: ParamsView, x: &[f32], b: usize) -> Result<Vec<f32>> {
        let ip = match &self.opt {
            Some(o) => &o.infer,
            None => bail!("planned inference without a program"),
        };
        let (t, d) = (self.graph.plan.tokens, self.graph.plan.dim);
        let (image, patch) = (self.graph.plan.image, self.graph.plan.patch);
        let folded = self.folded_const(params);
        let sc = |r: BufRange| BufRange { off: r.off * b, len: r.len * b };
        INFER_ARENA.with(|cell| {
            let mut arena = cell.take();
            let need = ip.arena_elems * b;
            if arena.len() < need {
                arena.resize(need, 0.0);
            }
            let ap = arena.as_mut_ptr();
            let mut mean = INFER_MEAN.with(|m| m.take());
            if mean.len() != d {
                mean = vec![0.0f32; d];
            }
            let mut out = Vec::new();
            let mut si = 0;
            while si < self.slots.len() {
                let slot = &self.slots[si];
                let sb = ip.steps[si];
                let fuse_gelu = self.passes.fuse()
                    && matches!(slot.bind, Bind::Dense { .. } | Bind::Wasi { .. })
                    && matches!(self.slots.get(si + 1).map(|s| &s.bind), Some(Bind::Gelu));
                match &slot.bind {
                    Bind::Patchify => {
                        let y = unsafe { aw(ap, sc(sb.out)) };
                        ops::patchify_into(x, b, image, patch, y);
                    }
                    Bind::Dense { w, b: bs, o, i, .. } => {
                        let bias = params.floats(bs)?;
                        let (y, xs) = unsafe { (aw(ap, sc(sb.out)), ar(ap, sc(sb.src))) };
                        let rows = xs.len() / *i;
                        linear_nt(params.weight(w)?, xs, rows, *i, *o, Some(bias), fuse_gelu, y);
                    }
                    Bind::Wasi { l, r, b: bs, o, k, i, .. } => {
                        {
                            let (h, xs) = unsafe { (aw(ap, sc(sb.a)), ar(ap, sc(sb.src))) };
                            let rows = xs.len() / *i;
                            linear_nt(params.weight(r)?, xs, rows, *i, *k, None, false, h);
                        }
                        let bias = params.floats(bs)?;
                        let (y, h) = unsafe { (aw(ap, sc(sb.out)), ar(ap, sc(sb.a))) };
                        let rows = h.len() / *k;
                        linear_nt(params.weight(l)?, h, rows, *k, *o, Some(bias), fuse_gelu, y);
                    }
                    Bind::Assemble { cls, pos } => {
                        let (tok, src) = unsafe { (aw(ap, sc(sb.out)), ar(ap, sc(sb.src))) };
                        if let Some(fv) = folded {
                            for bi in 0..b {
                                tok[bi * t * d..bi * t * d + d].copy_from_slice(&fv[..d]);
                                let srow = &src[bi * (t - 1) * d..(bi + 1) * (t - 1) * d];
                                tok[bi * t * d + d..(bi + 1) * t * d].copy_from_slice(srow);
                                for (o, p) in tok[bi * t * d + d..(bi + 1) * t * d]
                                    .iter_mut()
                                    .zip(&fv[d..])
                                {
                                    *o += p;
                                }
                            }
                        } else {
                            let clsv = params.floats(cls)?;
                            let posv = params.floats(pos)?;
                            for bi in 0..b {
                                tok[bi * t * d..bi * t * d + d].copy_from_slice(clsv);
                                let srow = &src[bi * (t - 1) * d..(bi + 1) * (t - 1) * d];
                                tok[bi * t * d + d..(bi + 1) * t * d].copy_from_slice(srow);
                                for (o, p) in
                                    tok[bi * t * d..(bi + 1) * t * d].iter_mut().zip(posv)
                                {
                                    *o += p;
                                }
                            }
                        }
                    }
                    Bind::LayerNorm { g, b: bs } => {
                        let gv = params.floats(g)?;
                        let bv = params.floats(bs)?;
                        let cur = unsafe { aw(ap, sc(sb.out)) };
                        ops::layer_norm_inplace(cur, gv, bv, g.numel());
                    }
                    Bind::SliceV => {
                        let (v, src) = unsafe { (aw(ap, sc(sb.out)), ar(ap, sc(sb.src))) };
                        let rows = src.len() / (3 * d);
                        for row in 0..rows {
                            v[row * d..(row + 1) * d]
                                .copy_from_slice(&src[row * 3 * d + 2 * d..(row + 1) * 3 * d]);
                        }
                    }
                    Bind::Mixing => {
                        let cur = unsafe { aw(ap, sc(sb.out)) };
                        ops::uniform_mix_scratch(cur, b, t, d, &mut mean);
                    }
                    Bind::Gelu => {
                        // Only reached when not fused into the linear
                        // above.
                        let cur = unsafe { aw(ap, sc(sb.out)) };
                        for v in cur.iter_mut() {
                            *v = kernels::gelu(*v);
                        }
                    }
                    Bind::ResidualSave => {
                        let (cpy, src) = unsafe { (aw(ap, sc(sb.a)), ar(ap, sc(sb.src))) };
                        cpy.copy_from_slice(src);
                    }
                    Bind::ResidualAdd => {
                        let (cur, res) = unsafe { (aw(ap, sc(sb.out)), ar(ap, sc(sb.a))) };
                        for (v, a) in cur.iter_mut().zip(res) {
                            *v += a;
                        }
                    }
                    Bind::TakeCls => {
                        let (cl, src) = unsafe { (aw(ap, sc(sb.out)), ar(ap, sc(sb.src))) };
                        for bi in 0..b {
                            cl[bi * d..(bi + 1) * d]
                                .copy_from_slice(&src[bi * t * d..bi * t * d + d]);
                        }
                    }
                    Bind::SoftmaxCe => {
                        out = unsafe { ar(ap, sc(sb.src)) }.to_vec();
                        break;
                    }
                }
                si += if fuse_gelu { 2 } else { 1 };
            }
            INFER_MEAN.with(|m| m.replace(mean));
            cell.replace(arena);
            Ok(out)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::demo::{write_demo_artifacts, DemoConfig};
    use super::*;
    use crate::data::synth::VisionTask;
    use crate::runtime::Manifest;

    fn demo_manifest(tag: &str) -> Manifest {
        let dir = std::env::temp_dir().join(format!("wasi_graph_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn plan_reconstructs_demo_vit() {
        let m = demo_manifest("plan");
        let entry = m.model("vit_demo_wasi_eps80").unwrap();
        let plan = ModelPlan::from_entry(entry).unwrap();
        assert_eq!(plan.image * plan.image * 3, entry.input_dim);
        assert_eq!(plan.classes, entry.classes);
        assert_eq!(plan.blocks.len(), plan.depth);
        // mlp linears factored, attention dense in the demo fixture
        for b in &plan.blocks {
            assert_eq!(b[0].form, LinearForm::Dense);
            assert!(matches!(b[2].form, LinearForm::Factored { .. }));
            assert!(matches!(b[3].form, LinearForm::Factored { .. }));
        }
    }

    #[test]
    fn plan_refuses_unknown_tensor() {
        let m = demo_manifest("refuse");
        let mut entry = m.model("vit_demo_vanilla").unwrap().clone();
        entry.param_spec.push(TensorSpec {
            name: "blocks.0.frobnicator.w".into(),
            shape: vec![1],
            offset: 0,
        });
        let err = ModelPlan::from_entry(&entry).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("frobnicator"), "{msg}");
    }

    #[test]
    fn plan_refuses_non_vit_spec() {
        let m = demo_manifest("nonvit");
        let mut entry = m.model("vit_demo_vanilla").unwrap().clone();
        // TinyDec-style spec: no patch-embed scaffolding.
        entry.param_spec = vec![TensorSpec {
            name: "tok_embed".into(),
            shape: vec![16, 8],
            offset: 0,
        }];
        assert!(ModelPlan::from_entry(&entry).is_err());
    }

    #[test]
    fn planner_emits_expected_node_program() {
        let m = demo_manifest("nodes");
        let entry = m.model("vit_demo_wasi_eps80").unwrap();
        let graph = LayerGraph::from_entry(entry).unwrap();
        let depth = graph.plan.depth;
        // Patchify/embed/Assemble + 13 nodes per block + norm/cls/head/ce.
        assert_eq!(graph.nodes.len(), 3 + 13 * depth + 4);
        assert!(matches!(graph.nodes.first().unwrap().op, Op::Patchify));
        assert!(matches!(graph.nodes.last().unwrap().op, Op::SoftmaxCe));
        let wasi = graph
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Wasi { .. }))
            .count();
        assert_eq!(wasi, 2 * depth, "mlp fc1/fc2 factored per block");
        let dense = graph
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Dense { .. }))
            .count();
        assert_eq!(dense, 2 * depth + 2, "qkv/proj per block + embed + head");
        // Update program: one SGD pass + one WSI refresh per factored
        // layer.
        assert_eq!(graph.updates.len(), 1 + 2 * depth);
        assert!(matches!(graph.updates[0], UpdateOp::SgdClipDecay));
    }

    #[test]
    fn grads_match_finite_differences_through_graph_executor() {
        let m = demo_manifest("fd");
        let entry = m.model("vit_demo_vanilla").unwrap();
        let graph = LayerGraph::from_entry(entry).unwrap();
        let mut exec = GraphExecutor::new(graph, entry).unwrap();
        let params = entry.load_params().unwrap();
        let mut task = VisionTask::new("fd", entry.classes, 16, 0.5, 4, 3);
        let (x, y, _) = task.batch_onehot(entry.batch);

        let logits = exec.forward_train(&params, &x).unwrap();
        let (_, _, dlogits) = exec.loss_and_grad(&logits, &y);
        let mut grads = vec![0.0f32; entry.params_len];
        exec.backward(&params, &dlogits, &mut grads).unwrap();

        // Probe a spread of tensors: embed, attn, mlp, ln, cls/pos, head.
        let probes = [
            ("embed.w", 3usize),
            ("blocks.0.mlp.fc1.w", 7),
            ("blocks.1.attn.proj.w", 11),
            ("blocks.0.ln2.g", 2),
            ("cls", 5),
            ("pos", 13),
            ("head.w", 1),
            ("head.b", 0),
        ];
        let h = 1e-2f32;
        let specs: Vec<TensorSpec> = probes
            .iter()
            .map(|(name, _)| exec.plan().spec(name).unwrap().clone())
            .collect();
        let mut loss_of = |p: &[f32]| -> f32 {
            let logits = exec.forward_train(p, &x).unwrap();
            exec.loss_and_grad(&logits, &y).0
        };
        for ((name, kidx), spec) in probes.iter().zip(&specs) {
            let idx = spec.offset + kidx.min(&(spec.numel() - 1));
            let mut up = params.clone();
            up[idx] += h;
            let lp = loss_of(&up);
            let mut dn = params.clone();
            dn[idx] -= h;
            let lm = loss_of(&dn);
            let fd = (lp - lm) / (2.0 * h);
            let an = grads[idx];
            assert!(
                (fd - an).abs() < 2e-2 * fd.abs().max(1.0),
                "{name}[{kidx}]: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn packed_f32_inference_is_bit_identical_to_flat() {
        let m = demo_manifest("packf32");
        for model in ["vit_demo_vanilla", "vit_demo_wasi_eps80"] {
            let entry = m.model(model).unwrap();
            let graph = LayerGraph::from_entry(entry).unwrap();
            let exec = GraphExecutor::new_infer(graph, entry).unwrap();
            let params = entry.load_params().unwrap();
            let packed = PackedParams::pack(entry, &params, Precision::F32).unwrap();
            assert_eq!(packed.params_len(), entry.params_len);
            assert_eq!(packed.bytes(), entry.params_len * 4);
            let mut task = VisionTask::new("pk", entry.classes, 16, 0.5, 4, 21);
            let (x, _, _) = task.batch_onehot(entry.batch);
            let flat = exec.infer(&params, &x, entry.batch).unwrap();
            let pk = exec.infer_packed(&packed, &x, entry.batch).unwrap();
            assert_eq!(
                flat.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pk.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{model}: F32 packing must be lossless"
            );
        }
    }

    #[test]
    fn packed_bf16_matches_rounded_flat_params_bitwise() {
        // The bf16 dequantizing GEMM performs the identical operation
        // sequence as the f32 GEMM over pre-rounded weights, so the two
        // paths must agree bit for bit — the packed path is exactly
        // "bf16 weight storage", not an approximation of it.
        let m = demo_manifest("packbf16");
        let entry = m.model("vit_demo_wasi_eps80").unwrap();
        let graph = LayerGraph::from_entry(entry).unwrap();
        let exec = GraphExecutor::new_infer(graph, entry).unwrap();
        let params = entry.load_params().unwrap();
        let packed = PackedParams::pack(entry, &params, Precision::Bf16).unwrap();
        assert!(packed.bytes() < entry.params_len * 4, "bf16 packing must shrink weights");
        let mut rounded = params.clone();
        for spec in &entry.param_spec {
            if is_gemm_weight(spec) {
                let range = spec.offset..spec.offset + spec.numel();
                crate::precision::round_bf16_inplace(&mut rounded[range]);
            }
        }
        let mut task = VisionTask::new("pk16", entry.classes, 16, 0.5, 4, 22);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let want = exec.infer(&rounded, &x, entry.batch).unwrap();
        let got = exec.infer_packed(&packed, &x, entry.batch).unwrap();
        assert_eq!(
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn packed_i8_inference_tracks_f32_logits() {
        let m = demo_manifest("packi8");
        let entry = m.model("vit_demo_vanilla").unwrap();
        let graph = LayerGraph::from_entry(entry).unwrap();
        let exec = GraphExecutor::new_infer(graph, entry).unwrap();
        let params = entry.load_params().unwrap();
        let packed = PackedParams::pack(entry, &params, Precision::I8).unwrap();
        // Weight tensors dominate the demo ViT, so int8 packing should
        // land well under half the f32 footprint.
        assert!(packed.bytes() * 2 < entry.params_len * 4, "{}", packed.bytes());
        let mut task = VisionTask::new("pk8", entry.classes, 16, 0.5, 4, 23);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let f32_logits = exec.infer(&params, &x, entry.batch).unwrap();
        let i8_logits = exec.infer_packed(&packed, &x, entry.batch).unwrap();
        assert_eq!(f32_logits.len(), i8_logits.len());
        let scale = f32_logits.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
        for (a, q) in f32_logits.iter().zip(&i8_logits) {
            assert!(
                (a - q).abs() < 0.15 * scale,
                "int8 logits drifted: {a} vs {q} (scale {scale})"
            );
        }
    }

    #[test]
    fn infer_fused_epilogues_match_training_forward() {
        let m = demo_manifest("fuse");
        for model in ["vit_demo_vanilla", "vit_demo_wasi_eps80"] {
            let entry = m.model(model).unwrap();
            let graph = LayerGraph::from_entry(entry).unwrap();
            let mut exec = GraphExecutor::new(graph, entry).unwrap();
            let params = entry.load_params().unwrap();
            let mut task = VisionTask::new("fuse", entry.classes, 16, 0.5, 4, 9);
            let (x, _, _) = task.batch_onehot(entry.batch);
            let train_logits = exec.forward_train(&params, &x).unwrap();
            let infer_logits = exec.infer(&params, &x, entry.batch).unwrap();
            assert_eq!(train_logits.len(), infer_logits.len());
            for (a, b) in train_logits.iter().zip(&infer_logits) {
                assert!((a - b).abs() < 1e-4, "{model}: {a} vs {b}");
            }
        }
    }
}

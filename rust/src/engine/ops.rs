//! The typed op vocabulary of the native layer-graph IR (DESIGN.md §4)
//! plus the shared shaping/activation math both executors (training and
//! inference) run.
//!
//! [`Op`] names every forward node the graph planner
//! (`engine::graph::LayerGraph`) can emit; each op has a backward dual
//! implemented by the graph executor.  [`UpdateOp`] names the
//! optimizer-side program (SGD with clip + decay, per-layer WSI
//! refresh) that runs after backward.  Latency attribution
//! (`eval::latency::node_attribution`, `wasi-train bench`) tags these
//! ops instead of re-deriving shapes.

/// Per-token layer-norm epsilon (mirrors `python/compile/model.py`).
pub const LN_EPS: f32 = 1e-6;

/// One forward op of the layer graph.  `Dense`/`Wasi` carry the layer
/// name they bind to in the flat parameter layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// (B, image²·3) flat images → (B, G², patch²·3) patch tokens.
    Patchify,
    /// CLS prepend + positional embedding: (B, G², D) → (B, T, D).
    Assemble,
    /// Per-token layer norm; `name` is the `{prefix}` of `.g`/`.b`.
    LayerNorm { name: String },
    /// Dense linear `y = x Wᵀ + b` (Eq. 1).
    Dense { name: String },
    /// WASI-factored linear `y = x Rᵀ Lᵀ + b` (Eq. 8) with ASI
    /// activation compression on the saved input.
    Wasi { name: String, k: usize },
    /// qkv output (…, 3D) → value path (…, D).
    SliceV,
    /// The fixed doubly-stochastic token mixing `(I + 11ᵀ/T)/2`
    /// standing in for softmax attention (DESIGN.md §4 substitution).
    Mixing,
    /// Elementwise GELU (pre-activation saved for backward; fused into
    /// the preceding linear's epilogue on the inference path).
    Gelu,
    /// Push the current activation for a later residual add.
    ResidualSave,
    /// Pop the matching saved activation and add it.
    ResidualAdd,
    /// (B, T, D) → (B, D): keep token 0.
    TakeCls,
    /// Softmax cross-entropy head (loss + dlogits).
    SoftmaxCe,
}

impl Op {
    /// Stable short label for latency attribution and logs.
    pub fn label(&self) -> String {
        match self {
            Op::Patchify => "patchify".into(),
            Op::Assemble => "assemble".into(),
            Op::LayerNorm { name } => format!("ln:{name}"),
            Op::Dense { name } => format!("dense:{name}"),
            Op::Wasi { name, k } => format!("wasi:{name}[K={k}]"),
            Op::SliceV => "slice_v".into(),
            Op::Mixing => "mixing".into(),
            Op::Gelu => "gelu".into(),
            Op::ResidualSave => "residual_save".into(),
            Op::ResidualAdd => "residual_add".into(),
            Op::TakeCls => "take_cls".into(),
            Op::SoftmaxCe => "softmax_ce".into(),
        }
    }
}

/// One optimizer-side step of the graph's update program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum UpdateOp {
    /// Global-norm gradient clip + decoupled weight decay + SGD over
    /// the whole flat parameter vector (mirrors the AOT train step).
    SgdClipDecay,
    /// One warm subspace-iteration refresh of a factored layer's
    /// `L`/`R` (Algorithm 1, factored form), in flat parameter space.
    WsiRefresh { name: String },
}

impl UpdateOp {
    pub fn label(&self) -> String {
        match self {
            UpdateOp::SgdClipDecay => "sgd_clip_decay".into(),
            UpdateOp::WsiRefresh { name } => format!("wsi_refresh:{name}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Shared shaping/activation math (both executors)
// ---------------------------------------------------------------------------

/// (B, image²·3) flat images -> (B, grid², patch²·3) patch tokens
/// (matches `model.py::patchify`'s reshape/transpose).
pub fn patchify(x: &[f32], b: usize, image: usize, patch: usize) -> Vec<f32> {
    let grid = image / patch;
    let pd = patch * patch * 3;
    let mut out = vec![0.0f32; b * grid * grid * pd];
    patchify_into(x, b, image, patch, &mut out);
    out
}

/// [`patchify`] into a caller-provided buffer (the arena pass's planned
/// walks).  Every element of `out` is written.
pub fn patchify_into(x: &[f32], b: usize, image: usize, patch: usize, out: &mut [f32]) {
    let grid = image / patch;
    let pd = patch * patch * 3;
    debug_assert_eq!(out.len(), b * grid * grid * pd);
    for bi in 0..b {
        for gy in 0..grid {
            for py in 0..patch {
                for gx in 0..grid {
                    for px in 0..patch {
                        for c in 0..3 {
                            let src = bi * image * image * 3
                                + ((gy * patch + py) * image + gx * patch + px) * 3
                                + c;
                            let dst = ((bi * grid + gy) * grid + gx) * pd
                                + (py * patch + px) * 3
                                + c;
                            out[dst] = x[src];
                        }
                    }
                }
            }
        }
    }
}

/// The fixed token mixing standing in for softmax attention:
/// `out = ((I + 11ᵀ/T) / 2) · v` per batch element — half identity,
/// half uniform attention.  Doubly stochastic, parameter-free, and
/// symmetric (so backward applies the same operator).
pub fn uniform_mix(v: &mut [f32], b: usize, t: usize, d: usize) {
    let mut mean = vec![0.0f32; d];
    uniform_mix_scratch(v, b, t, d, &mut mean);
}

/// [`uniform_mix`] with a caller-provided `d`-length mean scratch (the
/// arena pass's planned walks reuse it across steps).  The scratch is
/// re-zeroed per batch element exactly as [`uniform_mix`] does, so the
/// two are bit-identical.
pub fn uniform_mix_scratch(v: &mut [f32], b: usize, t: usize, d: usize, mean: &mut [f32]) {
    debug_assert_eq!(mean.len(), d);
    for bi in 0..b {
        mean.iter_mut().for_each(|m| *m = 0.0);
        let batch = &v[bi * t * d..(bi + 1) * t * d];
        for row in batch.chunks(d) {
            for (m, x) in mean.iter_mut().zip(row) {
                *m += x;
            }
        }
        for m in mean.iter_mut() {
            *m /= t as f32;
        }
        let batch = &mut v[bi * t * d..(bi + 1) * t * d];
        for row in batch.chunks_mut(d) {
            for (x, m) in row.iter_mut().zip(&mean) {
                *x = 0.5 * *x + 0.5 * m;
            }
        }
    }
}

/// Row-wise log-softmax over `classes`-wide rows.
pub fn log_softmax_rows(logits: &[f32], classes: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; logits.len()];
    for (row, chunk) in logits.chunks(classes).enumerate() {
        let m = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = chunk.iter().map(|v| (v - m).exp()).sum::<f32>().ln() + m;
        for (c, &v) in chunk.iter().enumerate() {
            out[row * classes + c] = v - lse;
        }
    }
    out
}

/// In-place per-row layer norm (the inference path, no stats saved).
pub fn layer_norm_inplace(x: &mut [f32], g: &[f32], b: &[f32], d: usize) {
    for row in x.chunks_mut(d) {
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let is = 1.0 / (var + LN_EPS).sqrt();
        for c in 0..d {
            row[c] = (row[c] - mu) * is * g[c] + b[c];
        }
    }
}

/// Per-row argmax over a flat (rows × classes) logit buffer — the one
/// prediction rule every inference path shares (NaN-safe via
/// `total_cmp`: a diverged run surfaces as bad accuracy, not a panic).
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_stable() {
        assert_eq!(Op::Dense { name: "embed".into() }.label(), "dense:embed");
        assert_eq!(Op::Wasi { name: "x".into(), k: 7 }.label(), "wasi:x[K=7]");
        assert_eq!(UpdateOp::WsiRefresh { name: "a.b".into() }.label(), "wsi_refresh:a.b");
    }

    #[test]
    fn uniform_mix_is_doubly_stochastic_fixed_point() {
        // A constant-over-tokens input is a fixed point of the mixing.
        let (b, t, d) = (2usize, 4usize, 3usize);
        let mut v = vec![0.0f32; b * t * d];
        for bi in 0..b {
            for tt in 0..t {
                for dd in 0..d {
                    v[(bi * t + tt) * d + dd] = (bi * d + dd) as f32;
                }
            }
        }
        let before = v.clone();
        uniform_mix(&mut v, b, t, d);
        for (x, y) in v.iter().zip(&before) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn log_softmax_rows_normalizes() {
        let logits = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        let lp = log_softmax_rows(&logits, 3);
        for row in lp.chunks(3) {
            let sum: f32 = row.iter().map(|v| v.exp()).sum();
            assert!((sum - 1.0).abs() < 1e-5, "{sum}");
        }
    }

    #[test]
    fn argmax_rows_is_nan_safe() {
        let logits = vec![1.0f32, 3.0, 2.0, f32::NAN, 0.5, -1.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
        assert_eq!(argmax_rows(&[], 3), Vec::<usize>::new());
    }
}

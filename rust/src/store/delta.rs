//! Delta records: a personalized variant persisted as ONLY its WASI
//! subspace factors (DESIGN.md §Variant store).
//!
//! A finished `persist:"delta"` job trained with subspace-only SGD
//! (`GraphExecutor::restrict_to_subspace`) differs from the shared
//! frozen base in exactly the factored layers' `.l`/`.r` tensors, so
//! those tensors — a few percent of the full vector — are all the
//! store writes.  [`extract_delta`] verifies that contract bit-exactly
//! before persisting anything: a job whose frozen region drifted from
//! the base is refused, never silently truncated.
//!
//! On-disk format (versioned, self-checking):
//!
//! ```text
//! magic "WSID" | u32 LE version | u32 LE header_len | header JSON
//!   | payload (tensor f32 data, LE, table order) | u64 LE FNV-1a hash
//! ```
//!
//! The header JSON carries the model name, training precision, the
//! base-params content hash (hex — u64 does not fit f64 exactly), and
//! the tensor table (name/shape/offset).  The trailing FNV-1a hash
//! covers every preceding byte; decode refuses corrupt records and
//! unknown versions with actionable messages.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::{DeltaOverlay, ModelPlan};
use crate::precision::{round_bf16_inplace, Precision};
use crate::runtime::ModelEntry;
use crate::util::json::{self, Json};

/// On-disk magic for delta records.
pub const DELTA_MAGIC: [u8; 4] = *b"WSID";
/// Current on-disk format version.
pub const DELTA_VERSION: u32 = 1;

/// FNV-1a over the little-endian bytes of an f32 slice — the
/// content hash identifying the frozen base a delta applies to.
pub fn params_hash(data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in data {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

fn fnv_bytes(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in data {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// One subspace factor tensor inside a delta record.
#[derive(Debug, Clone)]
pub struct DeltaTensor {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in the model's flat parameter vector (the executor's
    /// addressing; `DeltaOverlay` keys on it).
    pub offset: usize,
    pub data: Vec<f32>,
}

impl DeltaTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// A personalized variant reduced to its subspace factors.
#[derive(Debug, Clone)]
pub struct DeltaRecord {
    /// Manifest model name the record applies to.
    pub model: String,
    /// Precision the job trained at: a bf16 job's frozen region is the
    /// bf16-ROUNDED base, and [`DeltaRecord::apply`] reproduces exactly
    /// that.
    pub train_precision: Precision,
    /// [`params_hash`] of the RAW shared base the delta was extracted
    /// against (the pool's cached `initial_params`).
    pub base_hash: u64,
    pub tensors: Vec<DeltaTensor>,
}

impl DeltaRecord {
    /// Total factor elements.
    pub fn elems(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }

    /// Resident payload bytes (what the LRU budget charges per record).
    pub fn bytes(&self) -> usize {
        self.elems() * 4
    }

    /// Refuse a base vector that is not the one this delta was
    /// extracted against.
    pub fn check_base(&self, base: &[f32]) -> Result<()> {
        let h = params_hash(base);
        if h != self.base_hash {
            bail!(
                "delta record for model {} was extracted against base {:016x}, \
                 got {:016x} — the shared frozen base changed",
                self.model,
                self.base_hash,
                h
            );
        }
        Ok(())
    }

    /// Materialize the full personalized vector: base, rounded to the
    /// training storage grid when the job trained at bf16, with the
    /// factor tensors overlaid.  Bit-identical to the params the
    /// finished job held.
    pub fn apply(&self, base: &[f32]) -> Result<Vec<f32>> {
        self.check_base(base)?;
        let mut out = base.to_vec();
        if self.train_precision == Precision::Bf16 {
            round_bf16_inplace(&mut out);
        }
        for t in &self.tensors {
            if t.offset + t.data.len() > out.len() {
                bail!(
                    "delta tensor {} [{} @ {}] overruns params_len {}",
                    t.name,
                    t.data.len(),
                    t.offset,
                    out.len()
                );
            }
            out[t.offset..t.offset + t.data.len()].copy_from_slice(&t.data);
        }
        Ok(out)
    }

    /// Zero-copy overlay over the raw base for the f32 serving path.
    /// Only valid for f32-trained records: a bf16 job's frozen region
    /// is the rounded base, which an overlay over the raw base cannot
    /// represent — materialize via [`DeltaRecord::apply`] instead.
    pub fn overlay<'a>(&'a self, base: &'a [f32]) -> Result<DeltaOverlay<'a>> {
        if self.train_precision != Precision::F32 {
            bail!(
                "delta record trained at {} cannot overlay the raw base; \
                 materialize with apply() instead",
                self.train_precision
            );
        }
        self.check_base(base)?;
        let mut tensors: BTreeMap<usize, &[f32]> = BTreeMap::new();
        for t in &self.tensors {
            if tensors.insert(t.offset, &t.data).is_some() {
                bail!("delta record tensors collide at offset {}", t.offset);
            }
        }
        DeltaOverlay::new(base, tensors)
    }

    /// Encode to the versioned on-disk format.
    pub fn encode(&self) -> Vec<u8> {
        let tensors: Vec<Json> = self
            .tensors
            .iter()
            .map(|t| {
                json::obj(vec![
                    ("name", json::str(t.name.clone())),
                    ("shape", json::arr(t.shape.iter().map(|&s| json::num(s as f64)))),
                    ("offset", json::num(t.offset as f64)),
                ])
            })
            .collect();
        let header = json::obj(vec![
            ("base_hash", json::str(format!("{:016x}", self.base_hash))),
            ("model", json::str(self.model.clone())),
            ("tensors", Json::Arr(tensors)),
            ("train_precision", json::str(self.train_precision.to_string())),
        ])
        .to_string();
        let mut out = Vec::with_capacity(16 + header.len() + self.bytes());
        out.extend_from_slice(&DELTA_MAGIC);
        out.extend_from_slice(&DELTA_VERSION.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for t in &self.tensors {
            for v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let h = fnv_bytes(&out);
        out.extend_from_slice(&h.to_le_bytes());
        out
    }

    /// Decode a record, refusing truncation, corruption (trailing hash
    /// mismatch), and unknown format versions.
    pub fn decode(bytes: &[u8]) -> Result<DeltaRecord> {
        if bytes.len() < 20 {
            bail!("delta record truncated ({} bytes)", bytes.len());
        }
        if bytes[..4] != DELTA_MAGIC {
            bail!("not a delta record (bad magic)");
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != DELTA_VERSION {
            bail!(
                "delta record format version {version} is not supported \
                 (this build reads version {DELTA_VERSION}); re-persist the \
                 variant with a matching build or drop it with `store gc`"
            );
        }
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        let body = &bytes[..bytes.len() - 8];
        let actual = fnv_bytes(body);
        if stored != actual {
            bail!(
                "delta record corrupt: content hash {actual:016x} != stored {stored:016x}"
            );
        }
        let header_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        if 12 + header_len > body.len() {
            bail!("delta record header overruns payload");
        }
        let header_text = std::str::from_utf8(&bytes[12..12 + header_len])
            .context("delta record header is not UTF-8")?;
        let header = Json::parse(header_text).context("delta record header is not JSON")?;
        let model = header
            .req("model")?
            .as_str()
            .ok_or_else(|| anyhow!("header model must be a string"))?
            .to_string();
        let precision_text = header
            .req("train_precision")?
            .as_str()
            .ok_or_else(|| anyhow!("header train_precision must be a string"))?;
        let train_precision: Precision = precision_text.parse()?;
        let hash_hex = header
            .req("base_hash")?
            .as_str()
            .ok_or_else(|| anyhow!("header base_hash must be a string"))?;
        let base_hash = u64::from_str_radix(hash_hex, 16)
            .map_err(|e| anyhow!("bad base_hash {hash_hex:?}: {e}"))?;
        let table = header
            .req("tensors")?
            .as_arr()
            .ok_or_else(|| anyhow!("header tensors must be an array"))?;
        let payload = &body[12 + header_len..];
        let mut tensors = Vec::with_capacity(table.len());
        let mut cursor = 0usize;
        for t in table {
            let name = t
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow!("tensor name must be a string"))?
                .to_string();
            let shape = t.req("shape")?.usize_vec()?;
            let offset = t
                .req("offset")?
                .as_usize()
                .ok_or_else(|| anyhow!("tensor offset must be a number"))?;
            let numel = shape.iter().product::<usize>().max(1);
            if cursor + numel * 4 > payload.len() {
                bail!("delta tensor {name} overruns the payload");
            }
            let data: Vec<f32> = payload[cursor..cursor + numel * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            cursor += numel * 4;
            tensors.push(DeltaTensor { name, shape, offset, data });
        }
        if cursor != payload.len() {
            bail!(
                "delta record payload has {} trailing bytes after the tensor table",
                payload.len() - cursor
            );
        }
        Ok(DeltaRecord { model, train_precision, base_hash, tensors })
    }

    /// Write to `path` atomically (temp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("delta.tmp");
        std::fs::write(&tmp, self.encode())
            .with_context(|| format!("writing delta record {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("installing delta record {}", path.display()))?;
        Ok(())
    }

    /// Read and decode a record from `path`.
    pub fn load(path: &Path) -> Result<DeltaRecord> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading delta record {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("decoding {}", path.display()))
    }
}

/// Extract a finished job's delta record: the subspace factor tensors
/// from `trained`, after verifying bit-exactly that every frozen tensor
/// equals the expected base (the raw base for f32 jobs, the
/// bf16-rounded base for bf16 jobs).  A job whose frozen region drifted
/// — trained without `restrict_to_subspace`, or against another base —
/// is refused rather than persisted lossily.
pub fn extract_delta(
    entry: &ModelEntry,
    base: &[f32],
    trained: &[f32],
    train_precision: Precision,
) -> Result<DeltaRecord> {
    if base.len() != entry.params_len || trained.len() != entry.params_len {
        bail!(
            "extract_delta: params lengths {}/{} != manifest {}",
            base.len(),
            trained.len(),
            entry.params_len
        );
    }
    let plan = ModelPlan::from_entry(entry)?;
    let specs = plan.subspace_specs();
    if specs.is_empty() {
        bail!(
            "model {} has no factored (subspace) layers; nothing to persist \
             as a delta — use full persistence for vanilla variants",
            entry.name
        );
    }
    let mut in_subspace = vec![false; entry.params_len];
    for s in &specs {
        for flag in &mut in_subspace[s.offset..s.offset + s.numel()] {
            *flag = true;
        }
    }
    let expected: Vec<f32> = if train_precision == Precision::Bf16 {
        let mut e = base.to_vec();
        round_bf16_inplace(&mut e);
        e
    } else {
        base.to_vec()
    };
    for (i, (t, e)) in trained.iter().zip(&expected).enumerate() {
        if !in_subspace[i] && t.to_bits() != e.to_bits() {
            bail!(
                "model {}: frozen parameter at flat offset {i} drifted from the \
                 shared base ({e} -> {t}); the job did not train subspace-only, \
                 refusing to persist a lossy delta",
                entry.name
            );
        }
    }
    let tensors = specs
        .iter()
        .map(|s| DeltaTensor {
            name: s.name.clone(),
            shape: s.shape.clone(),
            offset: s.offset,
            data: trained[s.offset..s.offset + s.numel()].to_vec(),
        })
        .collect();
    Ok(DeltaRecord {
        model: entry.name.clone(),
        train_precision,
        base_hash: params_hash(base),
        tensors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::demo::{write_demo_artifacts, DemoConfig};
    use crate::runtime::Manifest;

    fn demo_manifest(tag: &str) -> Manifest {
        let dir = std::env::temp_dir().join(format!("wasi_store_delta_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    fn perturbed_delta(tag: &str) -> (crate::runtime::ModelEntry, Vec<f32>, DeltaRecord) {
        let m = demo_manifest(tag);
        let entry = m.model("vit_demo_wasi_eps80").unwrap().clone();
        let base = entry.load_params().unwrap();
        let plan = ModelPlan::from_entry(&entry).unwrap();
        let mut trained = base.clone();
        for s in plan.subspace_specs() {
            for v in &mut trained[s.offset..s.offset + s.numel()] {
                *v += 0.25;
            }
        }
        let rec = extract_delta(&entry, &base, &trained, Precision::F32).unwrap();
        (entry, base, rec)
    }

    #[test]
    fn encode_decode_round_trips_bitwise() {
        let (_, base, rec) = perturbed_delta("roundtrip");
        let back = DeltaRecord::decode(&rec.encode()).unwrap();
        assert_eq!(back.model, rec.model);
        assert_eq!(back.train_precision, rec.train_precision);
        assert_eq!(back.base_hash, rec.base_hash);
        assert_eq!(back.tensors.len(), rec.tensors.len());
        for (a, b) in rec.tensors.iter().zip(&back.tensors) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.offset, b.offset);
            let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb, "{}", a.name);
        }
        // Applying the decoded record reproduces the trained vector.
        let applied = back.apply(&base).unwrap();
        let direct = rec.apply(&base).unwrap();
        let lb: Vec<u32> = applied.iter().map(|v| v.to_bits()).collect();
        let rb: Vec<u32> = direct.iter().map(|v| v.to_bits()).collect();
        assert_eq!(lb, rb);
    }

    #[test]
    fn decode_refuses_version_mismatch_and_corruption() {
        let (_, _, rec) = perturbed_delta("refuse");
        let good = rec.encode();
        // Future version.
        let mut versioned = good.clone();
        versioned[4..8].copy_from_slice(&(DELTA_VERSION + 1).to_le_bytes());
        let err = DeltaRecord::decode(&versioned).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        // Flipped payload byte: hash check fires.
        let mut corrupt = good.clone();
        let mid = corrupt.len() / 2;
        corrupt[mid] ^= 0xff;
        let err = DeltaRecord::decode(&corrupt).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        // Truncation.
        assert!(DeltaRecord::decode(&good[..10]).is_err());
        assert!(DeltaRecord::decode(b"JUNK").is_err());
    }

    #[test]
    fn extract_refuses_frozen_drift_and_wrong_base() {
        let m = demo_manifest("drift");
        let entry = m.model("vit_demo_wasi_eps80").unwrap().clone();
        let base = entry.load_params().unwrap();
        let mut trained = base.clone();
        // Perturb a frozen tensor (embed.w sits outside the subspace).
        trained[0] += 1.0;
        let err = extract_delta(&entry, &base, &trained, Precision::F32).unwrap_err();
        assert!(format!("{err:#}").contains("drifted"), "{err:#}");
        // A record refuses to apply against a different base.
        let (_, base2, rec) = perturbed_delta("wrongbase");
        let mut other = base2.clone();
        other[0] += 1.0;
        assert!(rec.apply(&other).is_err());
    }

    #[test]
    fn vanilla_variant_has_no_subspace() {
        let m = demo_manifest("vanilla");
        let entry = m.model("vit_demo_vanilla").unwrap().clone();
        let base = entry.load_params().unwrap();
        let err = extract_delta(&entry, &base, &base, Precision::F32).unwrap_err();
        assert!(format!("{err:#}").contains("no factored"), "{err:#}");
    }

    #[test]
    fn bf16_record_applies_over_rounded_base() {
        let m = demo_manifest("bf16");
        let entry = m.model("vit_demo_wasi_eps80").unwrap().clone();
        let base = entry.load_params().unwrap();
        let plan = ModelPlan::from_entry(&entry).unwrap();
        // A bf16 job's params: rounded base with trained factors.
        let mut trained = base.clone();
        round_bf16_inplace(&mut trained);
        for s in plan.subspace_specs() {
            for v in &mut trained[s.offset..s.offset + s.numel()] {
                *v += 0.125;
            }
        }
        let rec = extract_delta(&entry, &base, &trained, Precision::Bf16).unwrap();
        let applied = rec.apply(&base).unwrap();
        let ab: Vec<u32> = applied.iter().map(|v| v.to_bits()).collect();
        let tb: Vec<u32> = trained.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, tb);
        // The zero-copy overlay path is f32-only by design.
        assert!(rec.overlay(&base).is_err());
    }
}

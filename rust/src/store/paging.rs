//! The variant store: write-through delta persistence plus an
//! in-memory resident set under a costmodel-driven byte budget, paged
//! by LRU (DESIGN.md §Variant store).
//!
//! Semantics the soak harness asserts as invariants (`--faults
//! evict-budget`):
//!
//! * **Write-through** — `put` installs the record on disk (atomic
//!   temp-file rename) before it becomes resident, so eviction is
//!   memory-only and can never lose a variant.
//! * **Exactly-once reload** — `get` holds the resident-set lock across
//!   the disk load, so concurrent requests for an evicted key perform
//!   one reload, not a thundering herd.
//! * **Never evict the working record** — the key being inserted or
//!   served is exempt from eviction, so a single record larger than the
//!   whole budget still serves (the budget degrades to
//!   one-resident-at-a-time, not to failure).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::delta::DeltaRecord;

/// Counters + occupancy snapshot (`store-stats`, bench, soak report).
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    /// Records currently resident in memory.
    pub resident: usize,
    /// Payload bytes of the resident set.
    pub resident_bytes: usize,
    /// The configured byte budget (0 = unlimited).
    pub budget_bytes: usize,
    /// Records on disk.
    pub disk_records: usize,
    /// Total on-disk bytes.
    pub disk_bytes: u64,
    /// `get` calls served from the resident set.
    pub hits: u64,
    /// `get` calls that had to touch disk.
    pub misses: u64,
    /// Disk loads performed (exactly-once per evicted key per miss).
    pub reloads: u64,
    /// Records paged out of the resident set.
    pub evictions: u64,
    /// Records installed via `put`.
    pub puts: u64,
}

struct Resident {
    map: BTreeMap<String, Arc<DeltaRecord>>,
    /// LRU order, coldest first.
    order: Vec<String>,
    bytes: usize,
    hits: u64,
    misses: u64,
    reloads: u64,
    evictions: u64,
    puts: u64,
}

impl Resident {
    fn touch(&mut self, key: &str) {
        self.order.retain(|k| k != key);
        self.order.push(key.to_string());
    }

    fn drop_key(&mut self, key: &str) -> bool {
        if let Some(rec) = self.map.remove(key) {
            self.bytes -= rec.bytes();
            self.order.retain(|k| k != key);
            true
        } else {
            false
        }
    }

    /// Page out coldest-first until within budget; `protect` (the key
    /// being installed or served) is exempt.
    fn evict_over_budget(&mut self, budget: usize, protect: &str) {
        if budget == 0 {
            return;
        }
        while self.bytes > budget {
            let Some(victim) = self.order.iter().find(|k| k.as_str() != protect).cloned()
            else {
                break;
            };
            self.drop_key(&victim);
            self.evictions += 1;
        }
    }
}

/// Per-user subspace deltas over a shared frozen base, persisted to a
/// directory of `<key>.delta` files with an LRU-paged resident set.
pub struct VariantStore {
    dir: PathBuf,
    budget_bytes: usize,
    inner: Mutex<Resident>,
}

/// Keys become file names: restrict to a charset that cannot traverse
/// paths or collide with the `.delta` suffix handling.
fn check_key(key: &str) -> Result<()> {
    if key.is_empty()
        || !key
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
    {
        bail!(
            "invalid store key {key:?}: keys are nonempty [A-Za-z0-9_-] \
             (they become file names)"
        );
    }
    Ok(())
}

impl VariantStore {
    /// Open (creating if needed) a store directory with a resident-set
    /// byte budget (`0` = unlimited).
    pub fn open(dir: &Path, budget_bytes: usize) -> Result<VariantStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating store directory {}", dir.display()))?;
        Ok(VariantStore {
            dir: dir.to_path_buf(),
            budget_bytes,
            inner: Mutex::new(Resident {
                map: BTreeMap::new(),
                order: Vec::new(),
                bytes: 0,
                hits: 0,
                misses: 0,
                reloads: 0,
                evictions: 0,
                puts: 0,
            }),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.delta"))
    }

    /// Persist a record (write-through: disk first, then resident).
    pub fn put(&self, key: &str, rec: DeltaRecord) -> Result<()> {
        check_key(key)?;
        rec.save(&self.path_for(key))?;
        let rec = Arc::new(rec);
        let mut inner = self.inner.lock().unwrap();
        // Replacing a resident record is not an eviction.
        let _ = inner.drop_key(key);
        inner.bytes += rec.bytes();
        inner.map.insert(key.to_string(), rec);
        inner.touch(key);
        inner.puts += 1;
        inner.evict_over_budget(self.budget_bytes, key);
        Ok(())
    }

    /// Fetch a record: resident-set hit, or a transparent exactly-once
    /// reload from disk (the lock is held across the load).
    pub fn get(&self, key: &str) -> Result<Arc<DeltaRecord>> {
        check_key(key)?;
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.map.get(key).cloned() {
            inner.hits += 1;
            inner.touch(key);
            return Ok(rec);
        }
        inner.misses += 1;
        let path = self.path_for(key);
        if !path.exists() {
            bail!("no delta record {key:?} in store {}", self.dir.display());
        }
        let rec = Arc::new(DeltaRecord::load(&path)?);
        inner.reloads += 1;
        inner.bytes += rec.bytes();
        inner.map.insert(key.to_string(), rec.clone());
        inner.touch(key);
        inner.evict_over_budget(self.budget_bytes, key);
        Ok(rec)
    }

    /// Whether `key` is currently resident (tests, soak invariants).
    pub fn is_resident(&self, key: &str) -> bool {
        self.inner.lock().unwrap().map.contains_key(key)
    }

    /// Drop a record everywhere: resident set AND disk (`forget`).
    /// Returns whether anything existed.
    pub fn remove(&self, key: &str) -> Result<bool> {
        check_key(key)?;
        let mut inner = self.inner.lock().unwrap();
        let was_resident = inner.drop_key(key);
        drop(inner);
        let path = self.path_for(key);
        if path.exists() {
            std::fs::remove_file(&path)
                .with_context(|| format!("removing delta record {}", path.display()))?;
            return Ok(true);
        }
        Ok(was_resident)
    }

    /// All on-disk records as `(key, file_bytes)`, sorted by key.
    pub fn list(&self) -> Result<Vec<(String, u64)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing store {}", self.dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(key) = name.strip_suffix(".delta") else { continue };
            out.push((key.to_string(), entry.metadata()?.len()));
        }
        out.sort();
        Ok(out)
    }

    /// Drop undecodable on-disk records (corruption, format-version
    /// mismatch) and their resident entries.  Returns the dropped keys.
    pub fn gc(&self) -> Result<Vec<String>> {
        let mut dropped = Vec::new();
        for (key, _) in self.list()? {
            if DeltaRecord::load(&self.path_for(&key)).is_err() {
                self.remove(&key)?;
                dropped.push(key);
            }
        }
        Ok(dropped)
    }

    /// Page out the entire resident set (each drop counts as an
    /// eviction).  The soak's bit-identity post-pass uses this to force
    /// the evict→reload path for every key.
    pub fn evict_all(&self) {
        let mut inner = self.inner.lock().unwrap();
        let keys: Vec<String> = inner.order.clone();
        for key in keys {
            if inner.drop_key(&key) {
                inner.evictions += 1;
            }
        }
    }

    /// Resident keys, coldest first.
    pub fn resident_keys(&self) -> Vec<String> {
        self.inner.lock().unwrap().order.clone()
    }

    /// Counter + occupancy snapshot (scans the directory for the disk
    /// side).
    pub fn stats(&self) -> Result<StoreStats> {
        let disk = self.list()?;
        let inner = self.inner.lock().unwrap();
        Ok(StoreStats {
            resident: inner.map.len(),
            resident_bytes: inner.bytes,
            budget_bytes: self.budget_bytes,
            disk_records: disk.len(),
            disk_bytes: disk.iter().map(|(_, b)| *b).sum(),
            hits: inner.hits,
            misses: inner.misses,
            reloads: inner.reloads,
            evictions: inner.evictions,
            puts: inner.puts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::delta::{DeltaRecord, DeltaTensor};
    use super::*;
    use crate::precision::Precision;

    fn record(seed: f32, elems: usize) -> DeltaRecord {
        DeltaRecord {
            model: "test".into(),
            train_precision: Precision::F32,
            base_hash: 7,
            tensors: vec![DeltaTensor {
                name: "blocks.0.mlp.fc1.l".into(),
                shape: vec![elems],
                offset: 0,
                data: (0..elems).map(|i| seed + i as f32).collect(),
            }],
        }
    }

    fn tmp_store(tag: &str, budget: usize) -> VariantStore {
        let dir = std::env::temp_dir().join(format!("wasi_store_paging_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        VariantStore::open(&dir, budget).unwrap()
    }

    #[test]
    fn lru_evicts_coldest_and_reloads_exactly_once() {
        // Budget fits two 16-element records (64 B each).
        let store = tmp_store("lru", 128);
        store.put("u1", record(1.0, 16)).unwrap();
        store.put("u2", record(2.0, 16)).unwrap();
        store.put("u3", record(3.0, 16)).unwrap();
        // u1 was coldest and paged out; u2/u3 resident.
        assert!(!store.is_resident("u1"));
        assert!(store.is_resident("u2") && store.is_resident("u3"));
        let s = store.stats().unwrap();
        assert_eq!((s.puts, s.evictions, s.disk_records), (3, 1, 3));
        // Reload u1: one miss, one reload, and the new coldest (u2)
        // pages out.
        let rec = store.get("u1").unwrap();
        assert_eq!(rec.tensors[0].data[0], 1.0);
        assert!(!store.is_resident("u2"));
        let s = store.stats().unwrap();
        assert_eq!((s.misses, s.reloads, s.evictions), (1, 1, 2));
        // Hits do not touch disk.
        store.get("u1").unwrap();
        let s = store.stats().unwrap();
        assert_eq!((s.hits, s.reloads), (1, 1));
    }

    #[test]
    fn oversized_record_stays_resident() {
        // One record is bigger than the whole budget: it must still
        // serve (the protect rule), alone.
        let store = tmp_store("oversize", 32);
        store.put("big", record(0.0, 64)).unwrap();
        assert!(store.is_resident("big"));
        store.put("big2", record(1.0, 64)).unwrap();
        assert!(store.is_resident("big2"));
        assert!(!store.is_resident("big"));
        assert_eq!(store.get("big").unwrap().tensors[0].data[0], 0.0);
    }

    #[test]
    fn remove_drops_disk_and_resident() {
        let store = tmp_store("remove", 0);
        store.put("u1", record(1.0, 8)).unwrap();
        assert!(store.remove("u1").unwrap());
        assert!(!store.is_resident("u1"));
        assert!(store.get("u1").is_err());
        assert!(!store.remove("u1").unwrap());
    }

    #[test]
    fn gc_drops_corrupt_records() {
        let store = tmp_store("gc", 0);
        store.put("good", record(1.0, 8)).unwrap();
        std::fs::write(store.dir().join("bad.delta"), b"garbage").unwrap();
        let dropped = store.gc().unwrap();
        assert_eq!(dropped, vec!["bad".to_string()]);
        assert_eq!(store.list().unwrap().len(), 1);
    }

    #[test]
    fn keys_are_validated() {
        let store = tmp_store("keys", 0);
        assert!(store.put("../evil", record(0.0, 4)).is_err());
        assert!(store.get("").is_err());
        assert!(store.remove("a/b").is_err());
    }

    #[test]
    fn evict_all_counts_evictions() {
        let store = tmp_store("evictall", 0);
        store.put("u1", record(1.0, 8)).unwrap();
        store.put("u2", record(2.0, 8)).unwrap();
        store.evict_all();
        assert_eq!(store.resident_keys().len(), 0);
        let s = store.stats().unwrap();
        assert_eq!(s.evictions, 2);
        // Transparent reload after a full page-out.
        assert!(store.get("u1").is_ok());
    }
}

//! The variant store (DESIGN.md §Variant store): per-user subspace
//! deltas over a shared frozen base, with on-disk persistence and
//! budget-driven LRU paging.
//!
//! The paper's resource-constrained thesis applied to serving: all
//! per-user state a personalized job produced lives in the WASI
//! subspace (`delta` module — factor tensors + metadata + content
//! hash, versioned binary format), so a pool fronts orders of
//! magnitude more users than full-model copies would allow.  Requests
//! apply a delta against the pool's cached frozen base at serve time —
//! zero-copy for the f32 path ([`crate::engine::DeltaOverlay`]), a
//! transient materialization for reduced-precision serving — and the
//! resident set pages under a costmodel-driven byte budget (`paging`
//! module), spilling cold users to disk and reloading them
//! transparently, exactly once, on the next request.

pub mod delta;
pub mod paging;

pub use delta::{extract_delta, params_hash, DeltaRecord, DeltaTensor, DELTA_VERSION};
pub use paging::{StoreStats, VariantStore};

//! WSI — Weight Subspace Iteration (paper §3.3, Algorithm 1).

use crate::linalg::matrix::Mat;
use crate::linalg::qr::gram_schmidt;
use crate::linalg::svd::svd;

/// Factored weight W ≈ L R with L (O, K), R (K, I).
#[derive(Debug, Clone)]
pub struct WsiFactors {
    pub l: Mat,
    pub r: Mat,
}

impl WsiFactors {
    /// Step 1 (t = 0): truncated SVD at explained-variance threshold ε
    /// (Eqs. 5-7).  Returns the factors and the full spectrum.
    pub fn init_svd(w: &Mat, eps: f64) -> (Self, Vec<f32>) {
        let d = svd(w);
        let k = d.rank_for_energy(eps);
        let (o, i) = (w.rows, w.cols);
        let mut l = Mat::zeros(o, k);
        for r in 0..o {
            for j in 0..k {
                l.data[r * k + j] = d.u.at(r, j) * d.s[j];
            }
        }
        let mut rm = Mat::zeros(k, i);
        for j in 0..k {
            rm.data[j * i..(j + 1) * i].copy_from_slice(&d.vt.data[j * i..(j + 1) * i]);
        }
        (WsiFactors { l, r: rm }, d.s)
    }

    pub fn k(&self) -> usize {
        self.l.cols
    }

    /// Materialize W = L R (test/inspection only — never on the hot path).
    pub fn materialize(&self) -> Mat {
        self.l.matmul(&self.r)
    }

    /// Algorithm 1, t > 0, factored form (DESIGN.md §2.1): one warm
    /// subspace-iteration step on the implicit W = L R.
    ///
    ///   R'ᵀ = Wᵀ L = Rᵀ (LᵀL);   L' = orth_GS(W R'ᵀ) = orth_GS(L (R R'ᵀ));
    ///   R'' = L'ᵀ W = (L'ᵀ L) R.
    ///
    /// Never materializes W; K×K-bounded except the two thin products.
    pub fn refresh(&mut self) {
        let ltl = self.l.matmul_tn(&self.l);        // (K, K)
        let rp = ltl.matmul(&self.r);               // (K, I)
        let rrt = self.r.matmul_nt(&rp);            // (K, K)
        let lp = gram_schmidt(&self.l.matmul(&rrt)); // (O, K)
        let lpl = lp.matmul_tn(&self.l);            // (K, K)
        self.r = lpl.matmul(&self.r);
        self.l = lp;
    }

    /// Algorithm 1 verbatim on a materialized W (the Fig. 3b ablation and
    /// the WSI-vs-SVD comparison run through this):
    ///   Rᵀ = Wᵀ L_{t-1};   L = orth_GS(W Rᵀ);   then re-project R = Lᵀ W
    /// so that W̃ = L Lᵀ W is the best approximation within span(L).
    pub fn refresh_materialized(w: &Mat, l_prev: &Mat) -> Self {
        let r0 = l_prev.matmul_tn(w);             // Rᵀ = Wᵀ L  ⇔  R = Lᵀ W (K, I)
        let l = gram_schmidt(&w.matmul_nt(&r0));  // L = orth(W Rᵀ) (O, K)
        let r = l.matmul_tn(w);                   // (K, I)
        WsiFactors { l, r }
    }

    /// SGD update of the factors with weight decay (Eq. 11 in factored
    /// form), followed by the subspace refresh.
    pub fn sgd_update(&mut self, dl: &Mat, dr: &Mat, lr: f32, weight_decay: f32, refresh: bool) {
        for (p, g) in self.l.data.iter_mut().zip(&dl.data) {
            *p -= lr * (g + weight_decay * *p);
        }
        for (p, g) in self.r.data.iter_mut().zip(&dr.data) {
            *p -= lr * (g + weight_decay * *p);
        }
        if refresh {
            self.refresh();
        }
    }
}

/// Random matrix with power-law singular spectrum s_j ∝ (j+1)^-alpha —
/// the "pretrained weight" premise (Radiya-Dixit & Wang 2020; used by the
/// eval harness for paper-scale layers and by tests).
pub fn powerlaw(o: usize, i: usize, alpha: f32, seed: u64) -> Mat {
    powerlaw_factored(o, i, alpha, seed, o.min(i)).2
}

/// Like [`powerlaw`] but also returns the exact rank-`k` WSI factors
/// (L = U_k Σ_k, R = V_kᵀ) built from the same construction — this is
/// what `init_svd` would compute, without paying a large-matrix SVD.
/// Used by benches and paper-scale eval comparisons.
pub fn powerlaw_factored(o: usize, i: usize, alpha: f32, seed: u64, k: usize) -> (Mat, Mat, Mat) {
    let mut rng = crate::data::rng::Pcg64::new(seed);
    let full = o.min(i);
    let k = k.min(full);
    let mut u = gram_schmidt(&Mat::random(o, full, &mut rng));
    let v = gram_schmidt(&Mat::random(i, full, &mut rng));
    // scale U's columns by the spectrum, then one threaded matmul:
    // W = (U diag(s)) Vᵀ.
    for r in 0..o {
        let row = &mut u.data[r * full..(r + 1) * full];
        for (j, x) in row.iter_mut().enumerate() {
            *x *= ((j + 1) as f32).powf(-alpha);
        }
    }
    let w = u.matmul_nt(&v);
    // truncated factors
    let mut l = Mat::zeros(o, k);
    for r in 0..o {
        l.data[r * k..(r + 1) * k].copy_from_slice(&u.data[r * full..r * full + k]);
    }
    let mut rt = Mat::zeros(k, i);
    for j in 0..k {
        for c in 0..i {
            rt.data[j * i + c] = v.at(c, j);
        }
    }
    (l, rt, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    #[test]
    fn init_svd_respects_energy() {
        let w = powerlaw(40, 30, 1.0, 1);
        let (f, s) = WsiFactors::init_svd(&w, 0.9);
        assert!(f.k() < 30, "k = {}", f.k());
        assert_eq!(s.len(), 30);
        // reconstruction captures >= 90% energy
        let rec = f.materialize();
        let res = rec.sub(&w).frob_norm();
        let rel = (res / w.frob_norm()).powi(2);
        assert!(rel <= 0.1 + 1e-3, "residual energy {rel}");
    }

    #[test]
    fn higher_eps_higher_rank() {
        let w = powerlaw(40, 30, 0.8, 2);
        let mut prev = 0;
        for eps in [0.4, 0.6, 0.8, 0.9, 0.99] {
            let (f, _) = WsiFactors::init_svd(&w, eps);
            assert!(f.k() >= prev);
            prev = f.k();
        }
    }

    #[test]
    fn refresh_preserves_product() {
        let w = powerlaw(30, 20, 1.0, 3);
        let (mut f, _) = WsiFactors::init_svd(&w, 0.8);
        let before = f.materialize();
        f.refresh();
        let after = f.materialize();
        let rel = after.sub(&before).frob_norm() / before.frob_norm();
        assert!(rel < 1e-3, "product drift {rel}");
        // L orthonormal after refresh
        let g = f.l.matmul_tn(&f.l);
        for i in 0..f.k() {
            for j in 0..f.k() {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.at(i, j) - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn refresh_tracks_gradient_updates() {
        // Simulate fine-tuning drift: W moves slowly; factored refresh
        // keeps L R close to the top-K SVD of the drifting W.
        let mut w = powerlaw(30, 20, 1.2, 4);
        let (mut f, _) = WsiFactors::init_svd(&w, 0.9);
        let k = f.k();
        let mut rng = Pcg64::new(5);
        for _ in 0..10 {
            // small random perturbation of W (stand-in for a grad step)
            let dw = Mat::random(30, 20, &mut rng);
            for (x, d) in w.data.iter_mut().zip(&dw.data) {
                *x += 1e-3 * d;
            }
            // factored engine sees the same perturbation through L,R grads:
            // dL = dW Rᵀ, dR = Lᵀ dW (chain rule of W = L R)
            let dl = dw.matmul_nt(&f.r);
            let dr2 = f.l.matmul_tn(&dw);
            for (p, g) in f.l.data.iter_mut().zip(&dl.data) {
                *p += 1e-3 * g * 0.5;
            }
            for (p, g) in f.r.data.iter_mut().zip(&dr2.data) {
                *p += 1e-3 * g * 0.5;
            }
            f.refresh();
        }
        // compare against the true top-k approximation of the drifted W
        let d = svd(&w);
        let best = d.reconstruct(k);
        let ours = f.materialize();
        let best_err = best.sub(&w).frob_norm();
        let our_err = ours.sub(&w).frob_norm();
        assert!(
            our_err <= best_err * 1.5 + 1e-4,
            "ours {our_err} vs best {best_err}"
        );
    }
}

//! Native training layers: dense (vanilla, Eqs. 1-3) and WASI-factored
//! (Eqs. 8-11).  These are the per-layer engines behind the latency
//! tables (Tab. 2/3, Fig. 8) and the WSI-vs-SVD ablation (Fig. 3b):
//! every paper claim about per-iteration *time* is measured through
//! these, so forward/backward here are real allocations and real FLOPs,
//! not cost-model numbers.

use crate::linalg::matrix::Mat;
use crate::linalg::tucker::Tensor;

use super::asi::{AsiCompressor, CompressedActivation};
use super::lowrank_grad::lowrank_grad_3d;
use super::wsi::WsiFactors;

/// Vanilla dense linear layer with standard backprop (stores the full
/// input activation — the Eq. 42 memory bottleneck, on purpose).
pub struct DenseLayer {
    pub w: Mat, // (O, I)
    saved_x: Option<Tensor>,
}

impl DenseLayer {
    pub fn new(w: Mat) -> Self {
        DenseLayer { w, saved_x: None }
    }

    /// Y = X Wᵀ (Eq. 1); stores X for backward.
    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        let i = *x.shape.last().unwrap();
        let rows = x.numel() / i;
        let xf = Mat::from_vec(rows, i, x.data.clone());
        let y = xf.matmul_nt(&self.w);
        self.saved_x = Some(x.clone());
        let mut shape = x.shape.clone();
        *shape.last_mut().unwrap() = self.w.rows;
        Tensor::from_vec(&shape, y.data)
    }

    /// Returns (dX, dW) per Eqs. 2-3.
    pub fn backward(&mut self, dy: &Tensor) -> (Tensor, Mat) {
        let x = self.saved_x.take().expect("forward before backward");
        let i = *x.shape.last().unwrap();
        let o = self.w.rows;
        let rows = x.numel() / i;
        let xf = Mat::from_vec(rows, i, x.data.clone());
        let dyf = Mat::from_vec(rows, o, dy.data.clone());
        let dw = dyf.matmul_tn(&xf); // (O, I)
        let dx = dyf.matmul(&self.w); // (rows, I)
        (Tensor::from_vec(&x.shape, dx.data), dw)
    }

    pub fn sgd(&mut self, dw: &Mat, lr: f32, wd: f32) {
        for (p, g) in self.w.data.iter_mut().zip(&dw.data) {
            *p -= lr * (g + wd * *p);
        }
    }

    /// Bytes held for backward (the activation-memory bottleneck).
    pub fn saved_bytes(&self) -> usize {
        self.saved_x.as_ref().map(|t| t.numel() * 4).unwrap_or(0)
    }
}

/// WASI linear layer: factored weights + ASI-compressed residuals.
pub struct WasiLayer {
    pub factors: WsiFactors,
    pub asi: AsiCompressor,
    saved: Option<(CompressedActivation, Tensor)>, // (X̃ factors, H = X Rᵀ is recomputed)
    pub refresh_every: usize,
    step_count: usize,
}

impl WasiLayer {
    pub fn new(factors: WsiFactors, asi: AsiCompressor) -> Self {
        WasiLayer { factors, asi, saved: None, refresh_every: 1, step_count: 0 }
    }

    pub fn k(&self) -> usize {
        self.factors.k()
    }

    /// Y = X Rᵀ Lᵀ (Eq. 8); compresses X via ASI and stores ONLY the
    /// Tucker factors (plus dy-side shapes) for backward.
    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        let i = *x.shape.last().unwrap();
        let rows = x.numel() / i;
        let xf = Mat::from_vec(rows, i, x.data.clone());
        let h = xf.matmul_nt(&self.factors.r); // (rows, K)
        let y = h.matmul_nt(&self.factors.l);  // (rows, O)
        let compressed = self.asi.compress(x);
        let mut hshape = x.shape.clone();
        *hshape.last_mut().unwrap() = self.k();
        self.saved = Some((compressed, Tensor::from_vec(&hshape, h.data)));
        let mut shape = x.shape.clone();
        *shape.last_mut().unwrap() = self.factors.l.rows;
        Tensor::from_vec(&shape, y.data)
    }

    /// Backward per Eqs. 9-10 with dL/dR from the f_LR chain.
    /// Returns (dX, dL, dR).
    pub fn backward(&mut self, dy: &Tensor) -> (Tensor, Mat, Mat) {
        let (compressed, h) = self.saved.take().expect("forward before backward");
        let o = self.factors.l.rows;
        let k = self.k();
        let rows = dy.numel() / o;
        let dyf = Mat::from_vec(rows, o, dy.data.clone());
        // Eq. 10: dX = dY L R (two thin matmuls)
        let dh = dyf.matmul(&self.factors.l); // (rows, K)
        let dx = dh.matmul(&self.factors.r);  // (rows, I)
        // dL = Σ dY ⊗ H  (uses the recomputed rank-space intermediate)
        let hf = Mat::from_vec(rows, k, h.data);
        let dl = dyf.matmul_tn(&hf); // (O, K)
        // dR via f_LR with dH in place of dY (see DESIGN.md §2.2)
        let mut dh_shape = dy.shape.clone();
        *dh_shape.last_mut().unwrap() = k;
        let dh_t = Tensor::from_vec(&dh_shape, dh.data);
        let dr = lowrank_grad_3d(
            &compressed.core,
            &compressed.factors[0],
            &compressed.factors[1],
            &compressed.factors[2],
            &dh_t,
        );
        let mut xshape = dy.shape.clone();
        *xshape.last_mut().unwrap() = self.factors.r.cols;
        (Tensor::from_vec(&xshape, dx.data), dl, dr)
    }

    /// SGD on the factors + periodic WSI refresh (Eq. 11 + Algorithm 1).
    pub fn sgd(&mut self, dl: &Mat, dr: &Mat, lr: f32, wd: f32) {
        self.step_count += 1;
        let refresh = self.refresh_every > 0 && self.step_count % self.refresh_every == 0;
        self.factors.sgd_update(dl, dr, lr, wd, refresh);
    }

    /// Bytes held for backward: Tucker core + factors + H (Eq. 44-ish;
    /// H is K-thin and recomputable — kept for speed, counted honestly).
    pub fn saved_bytes(&self) -> usize {
        self.saved
            .as_ref()
            .map(|(c, h)| {
                let f: usize = c.factors.iter().map(|m| m.data.len()).sum();
                (c.core.numel() + f + h.numel()) * 4
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn make_layers(o: usize, i: usize, dims: &[usize], eps: f64, seed: u64)
        -> (DenseLayer, WasiLayer) {
        let w = crate::wasi::wsi::powerlaw(o, i, 1.0, seed);
        let (factors, _) = WsiFactors::init_svd(&w, eps);
        let ranks = vec![dims[0].min(6), dims[1].min(8), i.min(10)];
        let asi = AsiCompressor::new(dims, &ranks, seed ^ 1);
        (DenseLayer::new(w), WasiLayer::new(factors, asi))
    }

    #[test]
    fn forward_close_to_dense_at_high_eps() {
        let dims = [4usize, 9, 16];
        let (mut dense, mut wasi) = make_layers(12, 16, &dims, 0.999, 3);
        let mut rng = Pcg64::new(5);
        let x = Tensor::from_vec(&dims, rng.normal_vec(dims.iter().product()));
        let yd = dense.forward(&x);
        let yw = wasi.forward(&x);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in yw.data.iter().zip(&yd.data) {
            num += ((a - b) * (a - b)) as f64;
            den += (b * b) as f64;
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.05, "forward relative err {rel}");
    }

    #[test]
    fn wasi_saves_memory() {
        let dims = [8usize, 32, 64];
        let (mut dense, mut wasi) = make_layers(128, 64, &dims, 0.8, 7);
        let mut rng = Pcg64::new(8);
        let x = Tensor::from_vec(&dims, rng.normal_vec(dims.iter().product()));
        dense.forward(&x);
        wasi.forward(&x);
        assert!(
            wasi.saved_bytes() < dense.saved_bytes(),
            "wasi {} vs dense {}",
            wasi.saved_bytes(),
            dense.saved_bytes()
        );
    }

    #[test]
    fn training_reduces_loss() {
        // Tiny regression task through a single WASI layer: loss must drop.
        let dims = [4usize, 6, 10];
        let (_, mut wasi) = make_layers(5, 10, &dims, 0.95, 11);
        let mut rng = Pcg64::new(12);
        let x = Tensor::from_vec(&dims, rng.normal_vec(dims.iter().product()));
        let target = Tensor::from_vec(&[4, 6, 5], rng.normal_vec(4 * 6 * 5));
        let mut losses = Vec::new();
        // burn in the ASI bases before measuring
        for it in 0..80 {
            let y = wasi.forward(&x);
            let mut dy = Tensor::zeros(&y.shape);
            let mut loss = 0.0f64;
            for ((d, yv), tv) in dy.data.iter_mut().zip(&y.data).zip(&target.data) {
                let e = yv - tv;
                loss += (e * e) as f64;
                *d = 2.0 * e / y.numel() as f32;
            }
            let (_dx, dl, dr) = wasi.backward(&dy);
            wasi.sgd(&dl, &dr, 0.1, 0.0);
            if it >= 5 {
                losses.push(loss);
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "losses {:?}",
            losses
        );
    }

    #[test]
    fn dense_backward_grads_match_fd() {
        // finite-difference check of dW on a tiny dense layer
        let mut rng = Pcg64::new(13);
        let w = Mat::random(3, 4, &mut rng);
        let x = Tensor::from_vec(&[2, 1, 4], rng.normal_vec(8));
        let mut layer = DenseLayer::new(w.clone());
        let y = layer.forward(&x);
        let dy = Tensor::from_vec(&y.shape, vec![1.0; y.numel()]);
        let (_, dw) = layer.backward(&dy);
        let f = |wm: &Mat| -> f64 {
            let mut l2 = DenseLayer::new(wm.clone());
            l2.forward(&x).data.iter().map(|v| *v as f64).sum()
        };
        let h = 1e-3f32;
        for idx in [0usize, 5, 11] {
            let mut wp = w.clone();
            wp.data[idx] += h;
            let mut wm = w.clone();
            wm.data[idx] -= h;
            let fd = (f(&wp) - f(&wm)) / (2.0 * h as f64);
            assert!(
                (fd - dw.data[idx] as f64).abs() < 1e-2 * fd.abs().max(1.0),
                "idx {idx}: fd {fd} vs {}",
                dw.data[idx]
            );
        }
    }
}

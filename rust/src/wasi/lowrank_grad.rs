//! f_LR — weight gradients computed entirely in the low-rank space
//! (paper App. A.1, Eqs. 15-18 for 3D and Eqs. 22-26 for 4D).

use crate::linalg::matrix::Mat;
use crate::linalg::tucker::Tensor;

/// 3D contraction chain (Eqs. 15-18).
///
/// Inputs: Tucker factors of the compressed activation
/// (core (r1,r2,r3), u1 (B,r1), u2 (N,r2), u3 (I,r3)) and the output
/// gradient dy (B,N,O) as a tensor.  Returns dW (O, I) with
/// dW[o,i] = Σ_{b,n} dy[b,n,o] · X̃[b,n,i], never reconstructing X̃.
pub fn lowrank_grad_3d(core: &Tensor, u1: &Mat, u2: &Mat, u3: &Mat, dy: &Tensor) -> Mat {
    let (b, n, o) = (dy.shape[0], dy.shape[1], dy.shape[2]);
    let (r1, r2, r3) = (core.shape[0], core.shape[1], core.shape[2]);
    debug_assert_eq!(u1.rows, b);
    debug_assert_eq!(u2.rows, n);
    let i_dim = u3.rows;

    // Eq. 15: Z1[n, o, p] = Σ_b dy[b,n,o] u1[b,p]
    let mut z1 = vec![0.0f32; n * o * r1];
    for bb in 0..b {
        for nn in 0..n {
            let dyrow = &dy.data[(bb * n + nn) * o..(bb * n + nn + 1) * o];
            let u1row = u1.row(bb);
            for (oo, &dv) in dyrow.iter().enumerate() {
                if dv == 0.0 {
                    continue;
                }
                let zrow = &mut z1[(nn * o + oo) * r1..(nn * o + oo + 1) * r1];
                for (z, &u) in zrow.iter_mut().zip(u1row) {
                    *z += dv * u;
                }
            }
        }
    }

    // Eq. 16: Z2[p, s, n] = Σ_q core[p,q,s] u2[n,q]   (store as [p][n][s])
    let mut z2 = vec![0.0f32; r1 * n * r3];
    for p in 0..r1 {
        for nn in 0..n {
            let u2row = u2.row(nn);
            let out = &mut z2[(p * n + nn) * r3..(p * n + nn + 1) * r3];
            for q in 0..r2 {
                let uq = u2row[q];
                if uq == 0.0 {
                    continue;
                }
                let crow = &core.data[(p * r2 + q) * r3..(p * r2 + q + 1) * r3];
                for (o_, &cv) in out.iter_mut().zip(crow) {
                    *o_ += uq * cv;
                }
            }
        }
    }

    // Eq. 17: Z3[p, i, n] = Σ_s Z2[p,s,n] u3[i,s]  (stored [n][p][i] so the
    // Eq. 18 contraction becomes one contiguous matmul per token)
    let mut z3 = vec![0.0f32; n * r1 * i_dim];
    for p in 0..r1 {
        for nn in 0..n {
            let zrow = &z2[(p * n + nn) * r3..(p * n + nn + 1) * r3];
            let out = &mut z3[(nn * r1 + p) * i_dim..(nn * r1 + p + 1) * i_dim];
            for ii in 0..i_dim {
                let u3row = u3.row(ii);
                let mut s = 0.0f32;
                for (zv, uv) in zrow.iter().zip(u3row) {
                    s += zv * uv;
                }
                out[ii] = s;
            }
        }
    }

    // Eq. 18: dW[o, i] = Σ_{n, p} Z1[n,o,p] Z3[n,p,i] — per token nn this
    // is a (O x r1)·(r1 x I) matmul accumulated into dW (the dominant
    // term of Eq. 38: r1·I·O·N FLOPs).  The n-loop runs INSIDE an output
    // row block so each dW block stays cache-resident across all tokens
    // instead of streaming the full O x I matrix N times from memory.
    let mut dw = Mat::zeros(o, i_dim);
    const ROW_BLOCK: usize = 64;
    let mut oo0 = 0;
    while oo0 < o {
        let rows = ROW_BLOCK.min(o - oo0);
        let dw_block = &mut dw.data[oo0 * i_dim..(oo0 + rows) * i_dim];
        for nn in 0..n {
            let z1_slab = &z1[(nn * o + oo0) * r1..(nn * o + oo0 + rows) * r1];
            let z3_slab = &z3[nn * r1 * i_dim..(nn + 1) * r1 * i_dim];
            crate::linalg::kernels::gemm_nn_acc(z1_slab, rows, r1, z3_slab, i_dim, dw_block);
        }
        oo0 += rows;
    }
    dw
}

/// 4D contraction chain (Eqs. 22-26, the SwinLite path).
///
/// core (r1,r2,r3,r4); u1 (B,r1); u2 (H,r2); u3 (W,r3); u4 (I,r4);
/// dy (B,H,W,O) -> dW (O, I).
pub fn lowrank_grad_4d(
    core: &Tensor,
    u1: &Mat,
    u2: &Mat,
    u3: &Mat,
    u4: &Mat,
    dy: &Tensor,
) -> Mat {
    let (b, h, w, o) = (dy.shape[0], dy.shape[1], dy.shape[2], dy.shape[3]);
    let (r1, r2, r3, r4) = (core.shape[0], core.shape[1], core.shape[2], core.shape[3]);
    let i_dim = u4.rows;

    // Eq. 22: Z1[p,h,w,o] = Σ_b dy[b,h,w,o] u1[b,p]
    let mut z1 = vec![0.0f32; r1 * h * w * o];
    for bb in 0..b {
        let u1row = u1.row(bb);
        for hh in 0..h {
            for ww in 0..w {
                let dyrow = &dy.data[((bb * h + hh) * w + ww) * o..((bb * h + hh) * w + ww + 1) * o];
                for (p, &up) in u1row.iter().enumerate() {
                    if up == 0.0 {
                        continue;
                    }
                    let zrow = &mut z1[((p * h + hh) * w + ww) * o..((p * h + hh) * w + ww + 1) * o];
                    for (z, &dv) in zrow.iter_mut().zip(dyrow) {
                        *z += up * dv;
                    }
                }
            }
        }
    }

    // Eq. 23: Z2[p,h,s,t] = Σ_q core[p,q,s,t] u2[h,q]
    let mut z2 = vec![0.0f32; r1 * h * r3 * r4];
    for p in 0..r1 {
        for hh in 0..h {
            let u2row = u2.row(hh);
            for q in 0..r2 {
                let uq = u2row[q];
                if uq == 0.0 {
                    continue;
                }
                let cbase = ((p * r2 + q) * r3) * r4;
                let zbase = ((p * h + hh) * r3) * r4;
                for st in 0..r3 * r4 {
                    z2[zbase + st] += uq * core.data[cbase + st];
                }
            }
        }
    }

    // Eq. 24: Z3[p,h,s,o] = Σ_w Z1[p,h,w,o] u3[w,s]
    let mut z3 = vec![0.0f32; r1 * h * r3 * o];
    for p in 0..r1 {
        for hh in 0..h {
            for ww in 0..w {
                let u3row = u3.row(ww);
                let z1row = &z1[((p * h + hh) * w + ww) * o..((p * h + hh) * w + ww + 1) * o];
                for (s, &us) in u3row.iter().enumerate() {
                    if us == 0.0 {
                        continue;
                    }
                    let zrow = &mut z3[((p * h + hh) * r3 + s) * o..((p * h + hh) * r3 + s + 1) * o];
                    for (z, &v) in zrow.iter_mut().zip(z1row) {
                        *z += us * v;
                    }
                }
            }
        }
    }

    // Eq. 25: Z4[p,h,i,s] = Σ_t Z2[p,h,s,t] u4[i,t]   (stored [p][h][s][i])
    let mut z4 = vec![0.0f32; r1 * h * r3 * i_dim];
    for p in 0..r1 {
        for hh in 0..h {
            for s in 0..r3 {
                let z2row = &z2[((p * h + hh) * r3 + s) * r4..((p * h + hh) * r3 + s + 1) * r4];
                let zout = &mut z4[((p * h + hh) * r3 + s) * i_dim..((p * h + hh) * r3 + s + 1) * i_dim];
                for ii in 0..i_dim {
                    let u4row = u4.row(ii);
                    let mut acc = 0.0f32;
                    for (zv, uv) in z2row.iter().zip(u4row) {
                        acc += zv * uv;
                    }
                    zout[ii] = acc;
                }
            }
        }
    }

    // Eq. 26: dW[o,i] = Σ_{h,p,s} Z3[p,h,s,o] Z4[p,h,s,i]
    let mut dw = Mat::zeros(o, i_dim);
    for p in 0..r1 {
        for hh in 0..h {
            for s in 0..r3 {
                let z3row = &z3[((p * h + hh) * r3 + s) * o..((p * h + hh) * r3 + s + 1) * o];
                let z4row = &z4[((p * h + hh) * r3 + s) * i_dim..((p * h + hh) * r3 + s + 1) * i_dim];
                for (oo, &zv) in z3row.iter().enumerate() {
                    if zv == 0.0 {
                        continue;
                    }
                    let dwrow = &mut dw.data[oo * i_dim..(oo + 1) * i_dim];
                    for (d, &z4v) in dwrow.iter_mut().zip(z4row) {
                        *d += zv * z4v;
                    }
                }
            }
        }
    }
    dw
}

/// Exact dense gradient dW = Σ dyᵀ x (Eq. 2), for tests and perplexity.
pub fn dense_grad(x: &Tensor, dy: &Tensor) -> Mat {
    let i_dim = *x.shape.last().unwrap();
    let o_dim = *dy.shape.last().unwrap();
    let rows = x.numel() / i_dim;
    debug_assert_eq!(rows, dy.numel() / o_dim);
    let xf = Mat::from_vec(rows, i_dim, x.data.clone());
    let dyf = Mat::from_vec(rows, o_dim, dy.data.clone());
    dyf.matmul_tn(&xf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;
    use crate::linalg::tucker::hosvd;

    #[test]
    fn matches_dense_grad_on_reconstruction() {
        // f_LR(compress(x), dy) == dense_grad(reconstruct(x), dy) exactly.
        let mut rng = Pcg64::new(1);
        let (b, n, i, o) = (4usize, 9, 12, 7);
        let x = Tensor::from_vec(&[b, n, i], rng.normal_vec(b * n * i));
        let dy = Tensor::from_vec(&[b, n, o], rng.normal_vec(b * n * o));
        let ranks = [3usize, 5, 6];
        let (core, factors) = hosvd(&x, &ranks);
        let fast = lowrank_grad_3d(&core, &factors[0], &factors[1], &factors[2], &dy);
        let rec = crate::linalg::tucker::tucker_reconstruct(&core, &factors);
        let exact = dense_grad(&rec, &dy);
        let mut max_err = 0.0f32;
        for (a, bb) in fast.data.iter().zip(&exact.data) {
            max_err = max_err.max((a - bb).abs());
        }
        let scale = exact.frob_norm().max(1e-6);
        assert!(max_err / scale < 1e-4, "relative max err {}", max_err / scale);
    }

    #[test]
    fn four_d_matches_dense_on_reconstruction() {
        let mut rng = Pcg64::new(5);
        let (b, h, w, i, o) = (3usize, 4, 5, 8, 6);
        let x = Tensor::from_vec(&[b, h, w, i], rng.normal_vec(b * h * w * i));
        let dy = Tensor::from_vec(&[b, h, w, o], rng.normal_vec(b * h * w * o));
        let ranks = [2usize, 3, 3, 5];
        let (core, f) = hosvd(&x, &ranks);
        let fast = lowrank_grad_4d(&core, &f[0], &f[1], &f[2], &f[3], &dy);
        let rec = crate::linalg::tucker::tucker_reconstruct(&core, &f);
        let exact = dense_grad(&rec, &dy);
        let scale = exact.frob_norm().max(1e-6);
        let mut max_err = 0.0f32;
        for (a, bb) in fast.data.iter().zip(&exact.data) {
            max_err = max_err.max((a - bb).abs());
        }
        assert!(max_err / scale < 1e-4, "relative err {}", max_err / scale);
    }

    #[test]
    fn four_d_full_rank_equals_exact() {
        let mut rng = Pcg64::new(6);
        let (b, h, w, i, o) = (2usize, 3, 3, 5, 4);
        let x = Tensor::from_vec(&[b, h, w, i], rng.normal_vec(b * h * w * i));
        let dy = Tensor::from_vec(&[b, h, w, o], rng.normal_vec(b * h * w * o));
        let (core, f) = hosvd(&x, &[b, h, w, i]);
        let fast = lowrank_grad_4d(&core, &f[0], &f[1], &f[2], &f[3], &dy);
        let exact = dense_grad(&x, &dy);
        for (a, bb) in fast.data.iter().zip(&exact.data) {
            assert!((a - bb).abs() < 1e-3, "{a} vs {bb}");
        }
    }

    #[test]
    fn full_rank_equals_exact() {
        let mut rng = Pcg64::new(2);
        let (b, n, i, o) = (3usize, 5, 6, 4);
        let x = Tensor::from_vec(&[b, n, i], rng.normal_vec(b * n * i));
        let dy = Tensor::from_vec(&[b, n, o], rng.normal_vec(b * n * o));
        let (core, f) = hosvd(&x, &[b, n, i]);
        let fast = lowrank_grad_3d(&core, &f[0], &f[1], &f[2], &dy);
        let exact = dense_grad(&x, &dy);
        for (a, bb) in fast.data.iter().zip(&exact.data) {
            assert!((a - bb).abs() < 1e-3, "{a} vs {bb}");
        }
    }
}

//! The paper's contribution, native-rust engine: WSI (§3.3 Algorithm 1),
//! ASI (§3.2 Algorithm 2), the f_LR low-rank gradient (App. A.1), and
//! rank selection (App. A.2, Eqs. 29-32).
//!
//! Two engines exist on purpose:
//! * the **AOT/HLO path** (runtime/ + coordinator/) — the deployed
//!   three-layer system, compute graphs lowered from JAX;
//! * this **native engine** — per-layer training in pure rust used by the
//!   WSI-vs-SVD ablation (Fig. 3b), the latency tables (Tab. 2/3, Fig. 8)
//!   where per-layer wallclock must be attributed, and the baselines that
//!   have no HLO artifact (AMC, SVD-LLM, LoRA).
//! Unit tests cross-check the two engines' math against each other via
//! the shared oracles.

pub mod asi;
pub mod layer;
pub mod lowrank_grad;
pub mod rank_select;
pub mod wsi;

pub use asi::AsiCompressor;
pub use layer::{DenseLayer, WasiLayer};
pub use rank_select::{plan_ranks, PerplexityTable, RankPlan};
pub use wsi::WsiFactors;

//! ASI — Activation Subspace Iteration (paper §3.2, Algorithm 2),
//! native engine.

use crate::data::rng::Pcg64;
use crate::linalg::matrix::Mat;
use crate::linalg::subspace::SubspaceState;
use crate::linalg::tucker::{mode_product, unfold, Tensor};

/// Per-layer activation compressor holding the warm-start bases for each
/// mode of the activation tensor.
#[derive(Debug, Clone)]
pub struct AsiCompressor {
    pub states: Vec<SubspaceState>,
    pub ranks: Vec<usize>,
}

/// Compressed activation: Tucker core + per-mode bases (what backward
/// stores instead of the full activation — Eq. 44 memory).
#[derive(Debug, Clone)]
pub struct CompressedActivation {
    pub core: Tensor,
    pub factors: Vec<Mat>,
}

impl AsiCompressor {
    /// Algorithm 2, t = 0: i.i.d. normal init of each V (here directly of
    /// each basis U, orthogonalized).
    pub fn new(dims: &[usize], ranks: &[usize], seed: u64) -> Self {
        assert_eq!(dims.len(), ranks.len());
        let mut rng = Pcg64::new(seed);
        let states = dims
            .iter()
            .zip(ranks)
            .map(|(&d, &r)| SubspaceState::random(d, r.min(d), &mut rng))
            .collect();
        AsiCompressor { states, ranks: ranks.to_vec() }
    }

    /// One warm-started compression (Algorithm 2 body): per mode,
    /// V = A_mᵀ U_prev; U = orth(A_m V); S = S ×_m Uᵀ.
    pub fn compress(&mut self, a: &Tensor) -> CompressedActivation {
        let mut core = a.clone();
        let mut factors = Vec::with_capacity(self.states.len());
        for (m, st) in self.states.iter_mut().enumerate() {
            let a_m = unfold(a, m);
            st.step(&a_m);
            core = mode_product(&core, &st.u.transpose(), m);
            factors.push(st.u.clone());
        }
        CompressedActivation { core, factors }
    }

    /// Memory (elements) of the compressed form (Eq. 44 / Eq. 31).
    pub fn memory_elems(&self, dims: &[usize]) -> usize {
        let core: usize = self
            .ranks
            .iter()
            .zip(dims)
            .map(|(&r, &d)| r.min(d))
            .product();
        let factors: usize = self
            .ranks
            .iter()
            .zip(dims)
            .map(|(&r, &d)| r.min(d) * d)
            .sum();
        core + factors
    }
}

impl CompressedActivation {
    /// Reconstruct the full tensor (tests / perplexity only).
    pub fn reconstruct(&self) -> Tensor {
        let mut out = self.core.clone();
        for (m, u) in self.factors.iter().enumerate() {
            out = mode_product(&out, u, m);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lowrank_tensor(dims: &[usize], ranks: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let core = Tensor::from_vec(ranks, rng.normal_vec(ranks.iter().product()));
        let mut t = core;
        for (m, (&d, &r)) in dims.iter().zip(ranks).enumerate() {
            let u = Mat::random(d, r, &mut rng);
            t = mode_product(&t, &u, m);
        }
        t
    }

    #[test]
    fn warm_compression_converges() {
        // Repeated compression of the same low-rank tensor must converge
        // to (near-)exact reconstruction as the bases lock on.
        let dims = [8usize, 12, 10];
        let ranks = [3usize, 4, 5];
        let t = lowrank_tensor(&dims, &ranks, 1);
        let mut c = AsiCompressor::new(&dims, &ranks, 2);
        let mut last_rel = f32::INFINITY;
        for it in 0..6 {
            let comp = c.compress(&t);
            let rec = comp.reconstruct();
            let mut err = 0.0f64;
            for (a, b) in rec.data.iter().zip(&t.data) {
                err += ((a - b) * (a - b)) as f64;
            }
            let rel = (err.sqrt() as f32) / t.frob_norm();
            if it >= 3 {
                assert!(rel < 0.05, "iteration {it}: rel {rel}");
            }
            last_rel = rel;
        }
        assert!(last_rel < 0.02, "final rel {last_rel}");
    }

    #[test]
    fn memory_matches_eq31() {
        let dims = [16usize, 65, 128];
        let ranks = [4usize, 12, 20];
        let c = AsiCompressor::new(&dims, &ranks, 3);
        assert_eq!(
            c.memory_elems(&dims),
            4 * 12 * 20 + 16 * 4 + 65 * 12 + 128 * 20
        );
        assert!(c.memory_elems(&dims) < dims.iter().product::<usize>());
    }

    #[test]
    fn ranks_clamped_to_dims() {
        let c = AsiCompressor::new(&[4, 6], &[10, 3], 4);
        assert_eq!(c.states[0].u.cols, 4);
        assert_eq!(c.states[1].u.cols, 3);
    }
}

//! Rank selection (paper App. A.2, Eqs. 29-32).
//!
//! Given the build-time perplexity table P ∈ R^{N×E} (Eq. 28) and the
//! per-(layer, threshold) activation memories M (Eq. 31), pick one
//! threshold index per layer:
//!
//! * **ASI / budgeted** (Eq. 30): minimize Σ perplexity subject to
//!   Σ memory ≤ B.  The paper calls this "recursive backtracking"; we
//!   implement it as a discretized-knapsack DP (exact on the discretized
//!   budget grid) plus an exact branch-and-bound for small instances —
//!   the §3.3(i) "search cost from exponential to linear" improvement.
//! * **WASI / budget-free** (Eq. 32): per-layer independent minimization
//!   of memory at the target pre-tuning perplexity (here: the caller's ε
//!   index), which decomposes layer-by-layer — linear time.

use anyhow::{bail, Result};

/// The build-time table imported from the manifest.
#[derive(Debug, Clone)]
pub struct PerplexityTable {
    pub layers: Vec<String>,
    pub eps_grid: Vec<f64>,
    /// `perplexity[layer][eps_idx]` (Eq. 28, Frobenius gradient gap).
    pub perplexity: Vec<Vec<f64>>,
    /// `memory[layer][eps_idx]` in elements (Eq. 31).
    pub memory: Vec<Vec<usize>>,
    /// `ranks[layer][eps_idx]` = per-mode activation ranks.
    pub ranks: Vec<Vec<Vec<usize>>>,
}

/// A selection: one threshold index per layer.
#[derive(Debug, Clone, PartialEq)]
pub struct RankPlan {
    pub choice: Vec<usize>,
    pub total_perplexity: f64,
    pub total_memory: usize,
}

impl PerplexityTable {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn validate(&self) -> Result<()> {
        let n = self.layers.len();
        let e = self.eps_grid.len();
        if self.perplexity.len() != n || self.memory.len() != n || self.ranks.len() != n {
            bail!("table rows inconsistent with layer count");
        }
        for l in 0..n {
            if self.perplexity[l].len() != e || self.memory[l].len() != e {
                bail!("layer {l} has wrong number of threshold entries");
            }
        }
        Ok(())
    }

    fn plan_from_choice(&self, choice: Vec<usize>) -> RankPlan {
        let total_perplexity = choice
            .iter()
            .enumerate()
            .map(|(l, &j)| self.perplexity[l][j])
            .sum();
        let total_memory = choice
            .iter()
            .enumerate()
            .map(|(l, &j)| self.memory[l][j])
            .sum();
        RankPlan { choice, total_perplexity, total_memory }
    }
}

/// Eq. 30: budgeted selection.  DP over a discretized budget grid
/// (resolution `grid` cells); exact for the discretization, and the unit
/// tests cross-check against exhaustive search on small instances.
pub fn plan_ranks(table: &PerplexityTable, budget_elems: usize, grid: usize) -> Result<RankPlan> {
    table.validate()?;
    let n = table.n_layers();
    let e = table.eps_grid.len();
    if n == 0 {
        bail!("empty table");
    }
    // Feasibility: every layer must fit at its cheapest setting.
    let min_total: usize = table.memory.iter().map(|row| row.iter().min().unwrap()).sum();
    if min_total > budget_elems {
        bail!("budget {budget_elems} elems infeasible (min {min_total})");
    }

    let cell = (budget_elems as f64 / grid as f64).max(1.0);
    let cells = (budget_elems as f64 / cell).floor() as usize + 1;
    const INF: f64 = f64::INFINITY;
    // dp[c] = min perplexity using <= c cells of memory, with choice trace.
    let mut dp = vec![INF; cells];
    let mut trace: Vec<Vec<usize>> = vec![Vec::new(); cells];
    dp[0] = 0.0;

    for l in 0..n {
        let mut ndp = vec![INF; cells];
        let mut ntrace: Vec<Vec<usize>> = vec![Vec::new(); cells];
        for c in 0..cells {
            if dp[c] == INF {
                continue;
            }
            for j in 0..e {
                let mem_cells = (table.memory[l][j] as f64 / cell).ceil() as usize;
                let nc = c + mem_cells;
                if nc >= cells {
                    continue;
                }
                let np = dp[c] + table.perplexity[l][j];
                if np < ndp[nc] {
                    ndp[nc] = np;
                    let mut t = trace[c].clone();
                    t.push(j);
                    ntrace[nc] = t;
                }
            }
        }
        dp = ndp;
        trace = ntrace;
    }

    let best = dp
        .iter()
        .enumerate()
        .filter(|(_, &p)| p.is_finite())
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(c, _)| c);
    match best {
        Some(c) => Ok(table.plan_from_choice(trace[c].clone())),
        None => bail!("no feasible plan under budget"),
    }
}

/// Exhaustive search (small instances; used to verify the DP in tests
/// and available for n_layers * E^n small enough).
pub fn plan_ranks_exhaustive(table: &PerplexityTable, budget_elems: usize) -> Option<RankPlan> {
    let n = table.n_layers();
    let e = table.eps_grid.len();
    let mut best: Option<RankPlan> = None;
    let mut choice = vec![0usize; n];
    loop {
        let plan = table.plan_from_choice(choice.clone());
        if plan.total_memory <= budget_elems {
            let better = match &best {
                None => true,
                Some(b) => plan.total_perplexity < b.total_perplexity,
            };
            if better {
                best = Some(plan);
            }
        }
        // increment mixed-radix counter
        let mut d = 0;
        loop {
            if d == n {
                return best;
            }
            choice[d] += 1;
            if choice[d] < e {
                break;
            }
            choice[d] = 0;
            d += 1;
        }
    }
}

/// Eq. 32: WASI budget-free selection — minimize memory at a uniform
/// threshold index (the paper evaluates a shared ε across layers; the
/// per-layer ranks then fall out of the table).  Linear time.
pub fn plan_ranks_wasi(table: &PerplexityTable, eps: f64) -> Result<RankPlan> {
    table.validate()?;
    let j = table
        .eps_grid
        .iter()
        .position(|&g| (g - eps).abs() < 1e-9)
        .ok_or_else(|| anyhow::anyhow!("eps {eps} not in grid {:?}", table.eps_grid))?;
    Ok(table.plan_from_choice(vec![j; table.n_layers()]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_table() -> PerplexityTable {
        // 3 layers x 3 thresholds; perplexity falls as memory rises.
        PerplexityTable {
            layers: vec!["a".into(), "b".into(), "c".into()],
            eps_grid: vec![0.4, 0.6, 0.8],
            perplexity: vec![
                vec![9.0, 4.0, 1.0],
                vec![8.0, 5.0, 2.0],
                vec![7.0, 3.0, 0.5],
            ],
            memory: vec![
                vec![10, 20, 40],
                vec![12, 25, 50],
                vec![8, 18, 35],
            ],
            ranks: vec![vec![vec![1], vec![2], vec![3]]; 3],
        }
    }

    #[test]
    fn dp_matches_exhaustive() {
        let t = toy_table();
        for budget in [30usize, 50, 70, 90, 125] {
            let dp = plan_ranks(&t, budget, 500).unwrap();
            let ex = plan_ranks_exhaustive(&t, budget).unwrap();
            assert!(
                dp.total_perplexity <= ex.total_perplexity + 1e-9,
                "budget {budget}: dp {} vs exhaustive {}",
                dp.total_perplexity,
                ex.total_perplexity
            );
            assert!(dp.total_memory <= budget);
        }
    }

    #[test]
    fn infeasible_budget_errors() {
        let t = toy_table();
        assert!(plan_ranks(&t, 5, 100).is_err());
    }

    #[test]
    fn bigger_budget_never_worse() {
        let t = toy_table();
        let mut prev = f64::INFINITY;
        for budget in [30usize, 45, 60, 90, 130] {
            let p = plan_ranks(&t, budget, 500).unwrap();
            assert!(p.total_perplexity <= prev + 1e-9);
            prev = p.total_perplexity;
        }
    }

    #[test]
    fn wasi_uniform_selection() {
        let t = toy_table();
        let p = plan_ranks_wasi(&t, 0.6).unwrap();
        assert_eq!(p.choice, vec![1, 1, 1]);
        assert_eq!(p.total_memory, 20 + 25 + 18);
        assert!(plan_ranks_wasi(&t, 0.55).is_err());
    }

    #[test]
    fn randomized_dp_vs_exhaustive() {
        use crate::util::proptest::{check, Gen};
        check("dp-optimal", 20, |g: &mut Gen| {
            let n = g.usize_in(1, 4);
            let e = g.usize_in(2, 4);
            let mut table = PerplexityTable {
                layers: (0..n).map(|i| format!("l{i}")).collect(),
                eps_grid: (0..e).map(|j| 0.1 * (j + 1) as f64).collect(),
                perplexity: Vec::new(),
                memory: Vec::new(),
                ranks: vec![vec![vec![1]; e]; n],
            };
            for _ in 0..n {
                // decreasing perplexity, increasing memory across thresholds
                let mut p: Vec<f64> = (0..e).map(|_| g.f32_in(0.1, 10.0) as f64).collect();
                p.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let mut m: Vec<usize> = (0..e).map(|_| g.usize_in(5, 60)).collect();
                m.sort();
                table.perplexity.push(p);
                table.memory.push(m);
            }
            let budget = g.usize_in(20, 200);
            let ex = plan_ranks_exhaustive(&table, budget);
            let dp = plan_ranks(&table, budget, 2000);
            match (ex, dp) {
                (None, Err(_)) => Ok(()),
                (Some(e_), Ok(d)) => {
                    if d.total_perplexity <= e_.total_perplexity + 1e-6 {
                        Ok(())
                    } else {
                        Err(format!(
                            "dp {} worse than exhaustive {}",
                            d.total_perplexity,
                            e_.total_perplexity
                        ))
                    }
                }
                (e_, d) => Err(format!("feasibility mismatch: {e_:?} vs {d:?}")),
            }
        });
    }
}

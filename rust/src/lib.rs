//! # wasi-train
//!
//! Production reproduction of *"Efficient Resource-Constrained Training
//! of Transformers via Subspace Optimization"* (WASI — Weight-Activation
//! Subspace Iteration) as a three-layer rust + JAX + Pallas system:
//!
//! * **L1** Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * **L2** JAX model + WASI math (build-time Python, lowered to HLO text)
//! * **L3** this crate: PJRT runtime, on-device training coordinator,
//!   native per-layer engine, baselines, cost model, device simulator,
//!   and the evaluation harness regenerating every paper table/figure.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod device;
pub mod eval;
pub mod linalg;
pub mod runtime;
pub mod util;
pub mod wasi;

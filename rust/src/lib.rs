//! # wasi-train
//!
//! Production reproduction of *"Efficient Resource-Constrained Training
//! of Transformers via Subspace Optimization"* (WASI — Weight-Activation
//! Subspace Iteration) as a three-layer rust + JAX + Pallas system:
//!
//! * **L1** Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * **L2** JAX model + WASI math (build-time Python, lowered to HLO text)
//! * **L3** this crate: artifact runtime, on-device training coordinator,
//!   native per-layer engine, baselines, cost model, device simulator,
//!   and the evaluation harness regenerating every paper table/figure.
//!
//! Training and inference execute behind the [`engine::TrainEngine`] /
//! [`engine::InferEngine`] traits with two implementations: the
//! AOT/HLO engine over the artifact runtime, and the pure-rust
//! [`engine::NativeModelEngine`] that reconstructs the model from the
//! manifest's `param_spec` — so the default build fine-tunes end to
//! end with no compiler runtime (`--engine {auto|hlo|native}`).
//!
//! The artifact runtime ([`runtime::Runtime`]) has two backends behind
//! one surface: a PJRT client over the `xla` crate (cargo feature
//! `pjrt`, off by default) and an always-available pure-rust
//! [`runtime::NativeRuntime`] fallback so the crate builds and runs
//! offline with zero external dependencies.
//!
//! Serving is job-oriented ([`serve`]): a [`serve::ModelPool`] loads
//! each artifact set once, a [`serve::Service`] schedules concurrent
//! fine-tuning jobs over fixed worker threads with cancellation and
//! streamed per-step events, and `wasi-train serve` exposes it all as
//! a JSON-lines session protocol.  The same protocol also serves many
//! concurrent clients over TCP (`serve --listen`): the [`net`] module
//! adds length-prefix framing, admission control, and cross-request
//! micro-batching of `infer` calls — coalesced requests run as one
//! stacked engine call, bit-identical to solo serving.  The blocking
//! [`coordinator::Session`] API and the CLI are thin clients of the
//! same core.  The [`scenario`] harness (`wasi-train soak`) drives
//! that core with replayed or synthesized adversarial workloads —
//! cancel storms, worker death, cache eviction, malformed frames —
//! and checks the serving invariants under sustained load.  Finished
//! personalized jobs persist as subspace delta records in a
//! [`store::VariantStore`] — factor tensors over the shared frozen
//! base, paged by LRU under a costmodel-driven memory budget
//! (`wasi-train store`, `serve --store`).
//!
//! See `DESIGN.md` (repository root) for the architecture and
//! `EXPERIMENTS.md` for the paper-vs-measured record.

// Style allowances for the whole crate: index loops intentionally
// mirror the paper's equations (clippy would rewrite them into
// iterator chains that obscure the math), and the numeric code uses
// the paper's single-letter tensor names.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity,
    clippy::new_without_default
)]

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod device;
pub mod engine;
pub mod eval;
pub mod linalg;
pub mod net;
pub mod precision;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod store;
pub mod util;
pub mod wasi;

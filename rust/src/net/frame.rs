//! Length-delimited framing for the socket transport.
//!
//! One frame = a 4-byte big-endian payload length followed by exactly
//! that many payload bytes.  The payload is one line of the existing
//! JSON protocol (`serve/proto.rs`), WITHOUT a trailing newline — the
//! length prefix replaces the newline as the record boundary, so
//! payloads may in principle contain any bytes (malformed UTF-8/JSON is
//! still answered in-band, exactly as on stdio).
//!
//! Framing errors are connection-fatal: a partial header/payload means
//! the peer died mid-frame, and an oversize length means the stream is
//! garbage or hostile — in both cases the reader drops the connection
//! rather than guessing at a resync point.  Everything *inside* a
//! well-formed frame is answered in-band and the connection lives on.

use std::io::{self, Read, Write};

/// Hard per-frame payload cap.  Generous for the protocol's largest
/// legitimate payload (an explicit `x` input batch serialized as JSON
/// numbers) while bounding what one connection can make the server
/// buffer.
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Write one frame: 4-byte big-endian length, then the payload.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame payload {} exceeds cap {MAX_FRAME_BYTES}", payload.len()),
        ));
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)
}

/// Read one frame.  `Ok(None)` is a clean end-of-stream (EOF exactly on
/// a frame boundary); EOF mid-header or mid-payload, and a length above
/// `max`, are errors.
pub fn read_frame(r: &mut impl Read, max: usize) -> io::Result<Option<Vec<u8>>> {
    let mut header = [0u8; 4];
    let mut got = 0usize;
    while got < header.len() {
        match r.read(&mut header[got..])? {
            0 if got == 0 => return Ok(None),
            0 => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid frame header",
                ))
            }
            n => got += n,
        }
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > max {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap {max}"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_round_trip_back_to_back() {
        let mut buf = Vec::new();
        let payloads: [&[u8]; 4] = [b"{}", b"", b"{\"cmd\":\"status\"}", &[0xff, 0x00, 0x7f]];
        for p in payloads {
            write_frame(&mut buf, p).unwrap();
        }
        let mut r = Cursor::new(buf);
        for p in payloads {
            assert_eq!(read_frame(&mut r, MAX_FRAME_BYTES).unwrap().as_deref(), Some(p));
        }
        assert!(read_frame(&mut r, MAX_FRAME_BYTES).unwrap().is_none());
    }

    #[test]
    fn eof_mid_frame_is_an_error_clean_eof_is_none() {
        // Clean EOF before any byte → None.
        assert!(read_frame(&mut Cursor::new(Vec::<u8>::new()), 64).unwrap().is_none());
        // Truncated header.
        let mut r = Cursor::new(vec![0u8, 0, 0]);
        assert_eq!(read_frame(&mut r, 64).unwrap_err().kind(), io::ErrorKind::UnexpectedEof);
        // Header promises more payload than the stream holds.
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        buf.truncate(buf.len() - 2);
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r, 64).unwrap_err().kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn oversize_frames_are_rejected_on_both_sides() {
        let mut r = Cursor::new(u32::MAX.to_be_bytes().to_vec());
        assert_eq!(read_frame(&mut r, 64).unwrap_err().kind(), io::ErrorKind::InvalidData);
        let big = vec![b'x'; 65];
        let mut w = Vec::new();
        write_frame(&mut w, &big).unwrap(); // cap is MAX_FRAME_BYTES, not 64
        let mut r = Cursor::new(w);
        assert_eq!(read_frame(&mut r, 64).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }
}

//! Dynamic micro-batching for `infer` requests (DESIGN.md §Network
//! front-end).
//!
//! Concurrent `infer` requests that resolve to the same pool entry and
//! parameter source — the [`BatchKey`]: artifact directory, variant,
//! engine, precision, and personalized job — are coalesced within a
//! short gather window into ONE stacked engine call
//! ([`crate::serve::Service::infer_batch`], which rides the
//! arena-planned batched graph walk), and the logits fan back out per
//! request.  Because every inference GEMM is row-independent with a
//! fixed accumulation order, the stacked call is bitwise identical to
//! serving each request alone (pinned in `tests/net.rs`); batching
//! changes throughput, never answers.
//!
//! Protocol: the first request to arrive for a key becomes the group
//! *leader*.  It waits up to the window for followers (a follower that
//! fills the group to `max_batch` seals it early), unpublishes the
//! group so later arrivals start a fresh one, executes, and publishes
//! per-request results; followers just wait on the group.  A failed
//! stacked call falls back to serving each member individually so one
//! request's bad input cannot fail its window-mates.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::engine::EngineKind;
use crate::precision::Precision;
use crate::serve::{InferOutput, InferRequest, JobId, Service};

use super::stats::NetStats;

/// The coalescing key: requests may share one stacked call only if
/// they would read the same weights through the same engine at the
/// same precision.  Seed and explicit inputs vary per request and are
/// deliberately NOT part of the key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    /// Artifact directory (`None` = the service default).
    pub artifacts: Option<PathBuf>,
    pub model: String,
    pub engine: EngineKind,
    pub precision: Precision,
    /// Personalized job whose params are served (`None` = pretrained).
    pub job: Option<JobId>,
}

/// Per-request result slot: the stacked call's per-request output, or
/// this request's own error (errors don't clone through `anyhow`, so
/// they fan out pre-rendered).
type Slot = std::result::Result<InferOutput, String>;

struct GroupState {
    reqs: Vec<InferRequest>,
    /// Once sealed no request may join; set by the leader after its
    /// window, or by the follower that fills the group.
    sealed: bool,
    done: Option<Vec<Slot>>,
}

struct Group {
    state: Mutex<GroupState>,
    cond: Condvar,
}

impl Group {
    fn new(first: InferRequest) -> Group {
        Group {
            state: Mutex::new(GroupState { reqs: vec![first], sealed: false, done: None }),
            cond: Condvar::new(),
        }
    }
}

/// The gather/execute engine.  One per server; also usable standalone
/// (the soak harness and `tests/net.rs` drive it directly).
pub struct Batcher {
    svc: Arc<Service>,
    window: Duration,
    max_batch: usize,
    stats: Arc<NetStats>,
    groups: Mutex<HashMap<BatchKey, Arc<Group>>>,
}

impl Batcher {
    pub fn new(svc: Arc<Service>, window_us: u64, max_batch: usize, stats: Arc<NetStats>) -> Self {
        Batcher {
            svc,
            window: Duration::from_micros(window_us),
            max_batch: max_batch.max(1),
            stats,
            groups: Mutex::new(HashMap::new()),
        }
    }

    /// Submit one request and block until its result is ready (the
    /// caller is a dispatcher thread; blocking here IS the gather
    /// window).  Returns exactly what a solo [`Service::infer`] call
    /// would, bit for bit.
    pub fn submit(&self, key: BatchKey, req: InferRequest) -> Result<InferOutput> {
        let (group, index, leader) = self.join_or_lead(&key, req);
        if leader {
            self.lead(&key, &group);
        }
        let st = group.state.lock().unwrap();
        let st = self.wait_done(&group, st);
        match &st.done.as_ref().expect("group published without results")[index] {
            Ok(out) => Ok(out.clone()),
            Err(msg) => Err(anyhow!("{msg}")),
        }
    }

    /// Join the key's open group as a follower, or register a fresh
    /// group and become its leader.
    fn join_or_lead(&self, key: &BatchKey, req: InferRequest) -> (Arc<Group>, usize, bool) {
        let mut groups = self.groups.lock().unwrap();
        if let Some(g) = groups.get(key) {
            let mut st = g.state.lock().unwrap();
            if !st.sealed && st.reqs.len() < self.max_batch {
                st.reqs.push(req);
                let index = st.reqs.len() - 1;
                let filled = st.reqs.len() >= self.max_batch;
                if filled {
                    st.sealed = true;
                }
                let g = g.clone();
                drop(st);
                if filled {
                    g.cond.notify_all();
                }
                return (g, index, false);
            }
        }
        let g = Arc::new(Group::new(req));
        groups.insert(key.clone(), g.clone());
        (g, 0, true)
    }

    /// Leader protocol: gather for the window, seal + unpublish,
    /// execute, publish.
    fn lead(&self, key: &BatchKey, group: &Arc<Group>) {
        if self.max_batch > 1 && !self.window.is_zero() {
            let st = group.state.lock().unwrap();
            let _ = self.cond_gather(group, st);
        }
        {
            let mut st = group.state.lock().unwrap();
            st.sealed = true;
        }
        {
            // Unpublish (only if the map still points at THIS group —
            // a filled group may already have been replaced).
            let mut groups = self.groups.lock().unwrap();
            if let Some(cur) = groups.get(key) {
                if Arc::ptr_eq(cur, group) {
                    groups.remove(key);
                }
            }
        }
        let reqs = group.state.lock().unwrap().reqs.clone();
        let slots = self.execute(key, &reqs);
        let mut st = group.state.lock().unwrap();
        st.done = Some(slots);
        drop(st);
        group.cond.notify_all();
    }

    fn cond_gather<'a>(
        &self,
        group: &'a Group,
        st: std::sync::MutexGuard<'a, GroupState>,
    ) -> std::sync::MutexGuard<'a, GroupState> {
        let (st, _) = group
            .cond
            .wait_timeout_while(st, self.window, |s| !s.sealed)
            .expect("batch group lock poisoned");
        st
    }

    fn wait_done<'a>(
        &self,
        group: &'a Group,
        st: std::sync::MutexGuard<'a, GroupState>,
    ) -> std::sync::MutexGuard<'a, GroupState> {
        group
            .cond
            .wait_while(st, |s| s.done.is_none())
            .expect("batch group lock poisoned")
    }

    /// Run a sealed group: one stacked call when it coalesced, with a
    /// per-request fallback on error.
    fn execute(&self, key: &BatchKey, reqs: &[InferRequest]) -> Vec<Slot> {
        let arts = key.artifacts.as_deref();
        if reqs.len() == 1 {
            self.stats.note_solo(1);
            return vec![self.svc.infer(arts, &reqs[0], key.job).map_err(|e| format!("{e:#}"))];
        }
        match self.svc.infer_batch(arts, reqs, key.job) {
            Ok(outs) => {
                self.stats.note_batch(reqs.len());
                outs.into_iter().map(Ok).collect()
            }
            Err(_) => {
                // One member's bad input (or a source that vanished
                // mid-window) must not fail the whole group: serve each
                // request alone so every member gets its own accurate
                // result or error.
                self.stats.note_solo(reqs.len());
                reqs.iter()
                    .map(|r| self.svc.infer(arts, r, key.job).map_err(|e| format!("{e:#}")))
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Correctness and coalescing behavior are pinned end-to-end in
    // `tests/net.rs` (they need demo artifacts); here we only pin the
    // group bookkeeping that needs no service.

    #[test]
    fn batch_key_distinguishes_every_field() {
        let base = BatchKey {
            artifacts: None,
            model: "m".into(),
            engine: EngineKind::Native,
            precision: Precision::F32,
            job: None,
        };
        let mut other = base.clone();
        assert_eq!(base, other);
        other.precision = Precision::I8;
        assert_ne!(base, other);
        let mut other = base.clone();
        other.job = Some(JobId(3));
        assert_ne!(base, other);
        let mut other = base.clone();
        other.artifacts = Some(PathBuf::from("/tmp/a"));
        assert_ne!(base, other);
        let mut other = base.clone();
        other.engine = EngineKind::Auto;
        assert_ne!(base, other);
    }
}

//! Front-end telemetry: lock-free global counters plus per-connection
//! gauges, surfaced through the `stats` protocol command and folded
//! into the soak report (`scenario/telemetry.rs` owns the latency
//! shapes; this module mirrors its fixed-edge histogram layout for
//! batch sizes).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::{self, Json};

/// Fixed batch-size histogram edges (`counts` has one extra overflow
/// bucket), mirroring `scenario::LatencyStats`'s fixed-edge layout so
/// dashboards treat both the same way.
pub const BATCH_EDGES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Global front-end counters.  Everything is monotonic except
/// `connections_open`, which is a live gauge.
#[derive(Debug, Default)]
pub struct NetStats {
    connections_opened: AtomicU64,
    connections_open: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    /// Requests answered `code:"overloaded"` at admission.
    rejections: AtomicU64,
    /// Infer requests served one-at-a-time (group of one, or the
    /// per-request fallback after a failed stacked call).
    infer_solo: AtomicU64,
    /// Infer requests served through a stacked micro-batch.
    infer_batched: AtomicU64,
    /// Stacked executions (each covers ≥ 2 requests).
    batches: AtomicU64,
    batch_hist: Mutex<[u64; BATCH_EDGES.len() + 1]>,
}

impl NetStats {
    pub fn connection_opened(&self) {
        self.connections_opened.fetch_add(1, Ordering::Relaxed);
        self.connections_open.fetch_add(1, Ordering::Relaxed);
    }

    pub fn connection_closed(&self) {
        self.connections_open.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn frame_in(&self) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
    }

    pub fn frame_out(&self) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
    }

    pub fn rejected(&self) {
        self.rejections.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` requests served individually.
    pub fn note_solo(&self, n: usize) {
        self.infer_solo.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record one stacked execution covering `n` requests.
    pub fn note_batch(&self, n: usize) {
        self.infer_batched.fetch_add(n as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        let idx = BATCH_EDGES.iter().position(|&edge| n <= edge).unwrap_or(BATCH_EDGES.len());
        self.batch_hist.lock().unwrap()[idx] += 1;
    }

    pub fn rejections(&self) -> u64 {
        self.rejections.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn infer_batched(&self) -> u64 {
        self.infer_batched.load(Ordering::Relaxed)
    }

    pub fn infer_solo(&self) -> u64 {
        self.infer_solo.load(Ordering::Relaxed)
    }

    pub fn connections_open(&self) -> u64 {
        self.connections_open.load(Ordering::Relaxed)
    }

    /// The gauges as protocol/report JSON.
    pub fn to_json(&self) -> Json {
        let count = |c: &AtomicU64| json::num(c.load(Ordering::Relaxed) as f64);
        let hist = self.batch_hist.lock().unwrap();
        json::obj(vec![
            ("connections_opened", count(&self.connections_opened)),
            ("connections_open", count(&self.connections_open)),
            ("frames_in", count(&self.frames_in)),
            ("frames_out", count(&self.frames_out)),
            ("admission_rejections", count(&self.rejections)),
            ("infer_solo", count(&self.infer_solo)),
            ("infer_batched", count(&self.infer_batched)),
            ("batches", count(&self.batches)),
            (
                "batch_size_histogram",
                json::obj(vec![
                    ("le", Json::Arr(BATCH_EDGES.iter().map(|&e| json::num(e as f64)).collect())),
                    ("counts", Json::Arr(hist.iter().map(|&c| json::num(c as f64)).collect())),
                ]),
            ),
        ])
    }
}

/// Per-connection gauges, listed under `connections` in the `stats`
/// response while the connection is open.
#[derive(Debug, Default)]
pub struct ConnStats {
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub rejections: AtomicU64,
}

impl ConnStats {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("frames_in", json::num(self.frames_in.load(Ordering::Relaxed) as f64)),
            ("frames_out", json::num(self.frames_out.load(Ordering::Relaxed) as f64)),
            ("rejections", json::num(self.rejections.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// Render an open-connection registry as the `connections` map of the
/// `stats` response (conn id → per-connection gauges).
pub fn connections_json<'a>(conns: impl Iterator<Item = (u64, &'a ConnStats)>) -> Json {
    let mut m = BTreeMap::new();
    for (id, stats) in conns {
        m.insert(format!("conn-{id}"), stats.to_json());
    }
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_histogram_buckets_and_counts_line_up() {
        let s = NetStats::default();
        s.note_batch(2);
        s.note_batch(2);
        s.note_batch(5); // → le 8
        s.note_batch(1000); // → overflow
        s.note_solo(3);
        assert_eq!(s.batches(), 3);
        assert_eq!(s.infer_batched(), 1009);
        assert_eq!(s.infer_solo(), 3);
        let j = s.to_json();
        let counts = j.get("batch_size_histogram").unwrap().get("counts").unwrap();
        let counts: Vec<u64> =
            counts.as_arr().unwrap().iter().map(|c| c.as_f64().unwrap() as u64).collect();
        assert_eq!(counts.len(), BATCH_EDGES.len() + 1);
        assert_eq!(counts[1], 2, "two batches of 2 in le=2");
        assert_eq!(counts[3], 1, "batch of 5 in le=8");
        assert_eq!(counts[BATCH_EDGES.len()], 1, "batch of 1000 overflows");
    }

    #[test]
    fn connection_gauge_tracks_open_and_close() {
        let s = NetStats::default();
        s.connection_opened();
        s.connection_opened();
        s.connection_closed();
        assert_eq!(s.connections_open(), 1);
        let j = s.to_json();
        assert_eq!(j.get("connections_opened").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("connections_open").unwrap().as_f64(), Some(1.0));
    }
}

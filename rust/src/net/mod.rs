//! Socket front-end for the job service: `wasi-train serve --listen`
//! (DESIGN.md §Network front-end).
//!
//! The stdio protocol ([`crate::serve::proto`]) is one session over one
//! pipe; this module multiplexes many concurrent TCP connections onto
//! the same [`crate::serve::Service`] without touching the protocol
//! itself.  Three pieces:
//!
//! * [`frame`] — length-prefix framing: each request/response line
//!   travels as a 4-byte big-endian length + payload, so partial reads,
//!   half-closes, and pipelined bursts are unambiguous;
//! * [`server`] — the listener: per-connection reader/writer threads
//!   over a shared bounded submission queue, framing-layer request
//!   `"id"`s threaded through so responses and streamed job events fan
//!   back to the right request, admission control
//!   (`--max-inflight` / `--queue-cap`, overload answered in-band as
//!   `{"ok":false,"code":"overloaded"}`), and graceful drain on
//!   shutdown;
//! * [`batcher`] — cross-request micro-batching: concurrent `infer`
//!   requests sharing one [`BatchKey`] coalesce within a gather window
//!   (`--batch-window-us` / `--max-batch`) into one stacked engine
//!   call, bit-identical to solo serving (pinned in `tests/net.rs`).
//!
//! [`stats`] carries the front-end telemetry (connections, queue
//! depth, batch-size histogram, admission rejections) surfaced by the
//! protocol's `stats` command and the soak report.

pub mod batcher;
pub mod frame;
pub mod server;
pub mod stats;

pub use batcher::{BatchKey, Batcher};
pub use frame::{read_frame, write_frame, MAX_FRAME_BYTES};
pub use server::{serve_listener, NetConfig, ServerHandle};
pub use stats::{ConnStats, NetStats, BATCH_EDGES};

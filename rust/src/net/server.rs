//! The TCP front-end: `wasi-train serve --listen ADDR` (DESIGN.md
//! §Network front-end).
//!
//! One accept loop hands each connection to a reader thread; each
//! connection also owns a writer thread fed by an in-process channel,
//! so a slow or dead peer can only ever stall its own writer — never a
//! dispatcher, never a service worker.  Readers validate framing
//! ([`super::frame`]), strip the framing-layer `"id"`, apply admission
//! control, and push admitted requests onto one shared bounded queue;
//! a small dispatcher pool drains it through the unchanged protocol
//! dispatcher ([`crate::serve::proto::handle_line`]), with `infer`
//! detoured through the micro-batcher ([`super::batcher::Batcher`]).
//! Responses — including every streamed `events` line — are re-tagged
//! with the request's `"id"` and framed back on the owning connection.
//!
//! Admission: a request is admitted only while both caps hold
//! (`in-flight < --max-inflight` and `queued < --queue-cap`);
//! otherwise it is answered in-band `{"ok":false,"code":"overloaded"}`
//! immediately — overload degrades to fast rejections, never to an
//! unresponsive socket.  `stats` and `shutdown` bypass admission (an
//! operator must be able to observe and stop an overloaded server).
//!
//! Shutdown: an accepted protocol `shutdown` (or
//! [`ServerHandle::shutdown`]) stops the accept loop, lets admitted
//! work drain (deadline-bounded — past it the service itself is shut
//! down, which cancels jobs and unblocks any event streams, exactly
//! like a stdio shutdown), then closes the sockets and joins every
//! thread.

use std::collections::{HashMap, VecDeque};
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::serve::proto::{self, Flow};
use crate::serve::Service;
use crate::util::json::{self, Json};

use super::batcher::{BatchKey, Batcher};
use super::frame::{read_frame, write_frame, MAX_FRAME_BYTES};
use super::stats::{connections_json, ConnStats, NetStats};

/// How long [`ServerHandle::shutdown`] waits for admitted work before
/// forcing the service down to unwedge it.
const DRAIN_DEADLINE: Duration = Duration::from_secs(10);

/// Socket front-end configuration (`serve --listen` flags).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:7777` (`:0` picks a free port).
    pub listen: String,
    /// Admission cap on admitted-but-unanswered requests.
    pub max_inflight: usize,
    /// Admission cap on the shared submission queue's depth.
    pub queue_cap: usize,
    /// Micro-batch gather window (0 disables batching).
    pub batch_window_us: u64,
    /// Micro-batch size cap (1 disables batching).
    pub max_batch: usize,
    /// Dispatcher threads draining the shared queue (0 = auto).
    pub dispatchers: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            listen: "127.0.0.1:0".to_string(),
            max_inflight: 64,
            queue_cap: 256,
            batch_window_us: 200,
            max_batch: 8,
            dispatchers: 0,
        }
    }
}

impl NetConfig {
    /// The dispatcher pool size actually used: enough parallelism for
    /// the batcher to observe concurrency (a gathering leader parks its
    /// dispatcher for the window), bounded so an idle server stays
    /// cheap.
    fn dispatcher_count(&self) -> usize {
        if self.dispatchers > 0 {
            self.dispatchers
        } else {
            self.max_inflight.min(16).max(2)
        }
    }
}

/// One admitted request, queued for (or being run by) a dispatcher.
struct Work {
    cmd: String,
    /// Framing-layer request id, re-attached to every response line.
    id: Option<Json>,
    /// The request line with `"id"` stripped — exactly what the stdio
    /// protocol would have read.
    line: String,
    reply: Sender<String>,
}

struct ConnReg {
    stream: TcpStream,
    stats: Arc<ConnStats>,
}

struct ServerShared {
    svc: Arc<Service>,
    cfg: NetConfig,
    addr: SocketAddr,
    stats: Arc<NetStats>,
    batcher: Batcher,
    queue: Mutex<VecDeque<Work>>,
    queue_cond: Condvar,
    stop: AtomicBool,
    stop_flag: Mutex<bool>,
    stop_cond: Condvar,
    inflight: AtomicUsize,
    conns: Mutex<HashMap<u64, ConnReg>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl ServerShared {
    fn register_thread(&self, h: JoinHandle<()>) {
        self.threads.lock().unwrap().push(h);
    }

    /// Flip the server into stopping mode (idempotent) and wake
    /// everything that might be parked: dispatchers, the stop waiter,
    /// and the accept loop (via a throwaway self-connection).
    fn trigger_stop(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue_cond.notify_all();
        {
            let mut stopped = self.stop_flag.lock().unwrap();
            *stopped = true;
            self.stop_cond.notify_all();
        }
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
    }
}

/// Bind `cfg.listen` and serve `svc` over it until a protocol
/// `shutdown` or [`ServerHandle::shutdown`].  Returns immediately; the
/// handle carries the resolved address (for `:0` binds) and the
/// front-end stats.
pub fn serve_listener(svc: Arc<Service>, cfg: NetConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.listen)
        .map_err(|e| anyhow!("cannot bind {}: {e}", cfg.listen))?;
    let addr = listener.local_addr()?;
    let stats = Arc::new(NetStats::default());
    let batcher =
        Batcher::new(svc.clone(), cfg.batch_window_us, cfg.max_batch, stats.clone());
    let shared = Arc::new(ServerShared {
        svc,
        addr,
        stats,
        batcher,
        queue: Mutex::new(VecDeque::new()),
        queue_cond: Condvar::new(),
        stop: AtomicBool::new(false),
        stop_flag: Mutex::new(false),
        stop_cond: Condvar::new(),
        inflight: AtomicUsize::new(0),
        conns: Mutex::new(HashMap::new()),
        threads: Mutex::new(Vec::new()),
        cfg,
    });
    for _ in 0..shared.cfg.dispatcher_count() {
        let s = shared.clone();
        shared.register_thread(std::thread::spawn(move || dispatcher_loop(&s)));
    }
    let accept = {
        let s = shared.clone();
        std::thread::spawn(move || accept_loop(&s, listener))
    };
    Ok(ServerHandle { shared, accept: Some(accept), finished: false })
}

/// A running socket front-end.  Dropping the handle shuts it down.
pub struct ServerHandle {
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
    finished: bool,
}

impl ServerHandle {
    /// The bound address (resolved port for `:0` binds).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// The front-end's telemetry counters.
    pub fn stats(&self) -> Arc<NetStats> {
        self.shared.stats.clone()
    }

    /// Block until the server starts stopping (an accepted protocol
    /// `shutdown`, or [`ServerHandle::shutdown`] from another thread).
    pub fn wait_stop(&self) {
        let stopped = self.shared.stop_flag.lock().unwrap();
        let _guard = self
            .shared
            .stop_cond
            .wait_while(stopped, |s| !*s)
            .expect("server stop lock poisoned");
    }

    /// Graceful drain: stop accepting, let admitted work finish
    /// (deadline-bounded — past [`DRAIN_DEADLINE`] the service is shut
    /// down to cancel whatever is wedging the drain, mirroring stdio
    /// shutdown semantics), then close the sockets and join every
    /// thread.  Idempotent; does NOT stop the service itself on the
    /// clean path — the caller owns that.
    pub fn shutdown(&mut self) {
        if self.finished {
            return;
        }
        self.finished = true;
        self.shared.trigger_stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let deadline = Instant::now() + DRAIN_DEADLINE;
        loop {
            let idle = self.shared.inflight.load(Ordering::SeqCst) == 0
                && self.shared.queue.lock().unwrap().is_empty();
            if idle {
                break;
            }
            if Instant::now() >= deadline {
                // Something holds the drain open (a streamed job that
                // never terminates, a wedged peer): shut the service
                // down — jobs cancel, event channels disconnect, and
                // every in-flight handler unblocks promptly.
                self.shared.svc.shutdown();
                break;
            }
            self.shared.queue_cond.notify_all();
            std::thread::sleep(Duration::from_millis(5));
        }
        // Unblock readers parked in read_frame and writers parked on a
        // full TCP buffer.
        for reg in self.shared.conns.lock().unwrap().values() {
            let _ = reg.stream.shutdown(Shutdown::Both);
        }
        self.shared.queue_cond.notify_all();
        // Join until the registry stays empty (threads register the
        // threads they spawn: conn readers register their writers).
        loop {
            let handles = std::mem::take(&mut *self.shared.threads.lock().unwrap());
            if handles.is_empty() {
                break;
            }
            for h in handles {
                let _ = h.join();
            }
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(shared: &Arc<ServerShared>, listener: TcpListener) {
    let mut next_conn = 0u64;
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        next_conn += 1;
        let conn_id = next_conn;
        let s = shared.clone();
        shared.register_thread(std::thread::spawn(move || conn_loop(&s, stream, conn_id)));
    }
}

/// Per-connection reader: owns the socket's read half for its whole
/// life, spawns the writer for the write half, and feeds admitted work
/// to the shared queue.
fn conn_loop(shared: &Arc<ServerShared>, stream: TcpStream, conn_id: u64) {
    let (Ok(write_half), Ok(read_half)) = (stream.try_clone(), stream.try_clone()) else {
        return;
    };
    shared.stats.connection_opened();
    let cstats = Arc::new(ConnStats::default());
    shared
        .conns
        .lock()
        .unwrap()
        .insert(conn_id, ConnReg { stream, stats: cstats.clone() });
    let (tx, rx) = mpsc::channel::<String>();
    {
        let (gstats, wstats) = (shared.stats.clone(), cstats.clone());
        shared.register_thread(std::thread::spawn(move || {
            writer_loop(write_half, rx, &gstats, &wstats)
        }));
    }
    let mut reader = BufReader::new(read_half);
    loop {
        match read_frame(&mut reader, MAX_FRAME_BYTES) {
            Ok(Some(payload)) => {
                shared.stats.frame_in();
                cstats.frames_in.fetch_add(1, Ordering::Relaxed);
                handle_payload(shared, payload, &tx, &cstats);
            }
            Ok(None) => break, // clean EOF (or half-close after a burst)
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // Oversize frame: report why, then drop the connection —
                // framing errors are connection-fatal (module docs).
                let err = error_json("?", &format!("framing error: {e}"), None);
                let _ = tx.send(attach_id(&err, &None));
                break;
            }
            Err(_) => break, // peer reset / died mid-frame
        }
    }
    shared.conns.lock().unwrap().remove(&conn_id);
    shared.stats.connection_closed();
    // Dropping `tx` lets the writer exit once in-flight handlers (which
    // hold their own reply senders) finish.
}

/// Per-connection writer: frames response lines in submission order.
/// Exits when every sender is gone (connection closed AND all its
/// in-flight work answered) or the peer stops accepting bytes.
fn writer_loop(
    stream: TcpStream,
    rx: Receiver<String>,
    stats: &NetStats,
    cstats: &ConnStats,
) {
    let mut w = BufWriter::new(stream);
    for line in rx {
        if write_frame(&mut w, line.as_bytes()).is_err() || w.flush().is_err() {
            // Peer gone: drain-and-drop whatever is still queued so
            // handlers never block on a dead connection.
            for _ in rx.iter() {}
            return;
        }
        stats.frame_out();
        cstats.frames_out.fetch_add(1, Ordering::Relaxed);
    }
}

/// Decode one frame, strip its `"id"`, and route it: `stats` inline,
/// `shutdown` admission-exempt, `events wait:true` to a dedicated
/// streamer thread, everything else through admission onto the shared
/// queue.
fn handle_payload(
    shared: &Arc<ServerShared>,
    payload: Vec<u8>,
    tx: &Sender<String>,
    cstats: &Arc<ConnStats>,
) {
    let text = match String::from_utf8(payload) {
        Ok(t) => t,
        Err(e) => {
            let err = error_json("?", &format!("frame is not valid UTF-8: {e}"), None);
            let _ = tx.send(attach_id(&err, &None));
            return;
        }
    };
    // Strip the framing-layer "id" so the protocol's strict key
    // validation never sees it; non-object frames pass through verbatim
    // and handle_line reports them exactly as it would on stdio.
    let (id, cmd, extra_keys, is_stream, line) = match Json::parse(text.trim()) {
        Ok(Json::Obj(mut m)) => {
            let id = m.remove("id");
            let cmd = m.get("cmd").and_then(|c| c.as_str()).unwrap_or("?").to_string();
            let extra = m.keys().any(|k| k != "cmd");
            let is_stream = cmd == "events" && m.get("wait") == Some(&Json::Bool(true));
            (id, cmd, extra, is_stream, Json::Obj(m).to_string())
        }
        _ => (None, "?".to_string(), false, false, text.trim().to_string()),
    };
    // `stats` answers from the reader thread so it works *under*
    // overload — that is the point of having it.  (With unexpected
    // keys it falls through so the protocol's key rejection answers.)
    if cmd == "stats" && !extra_keys {
        let mut fields = vec![("ok", Json::Bool(true)), ("cmd", json::str("stats"))];
        fields.extend(proto::service_stat_fields(&shared.svc));
        let mut m = match json::obj(fields) {
            Json::Obj(m) => m,
            _ => unreachable!("json::obj builds an object"),
        };
        m.insert("net".to_string(), shared.stats.to_json());
        let conns = shared.conns.lock().unwrap();
        m.insert(
            "connections".to_string(),
            connections_json(conns.iter().map(|(id, reg)| (*id, reg.stats.as_ref()))),
        );
        drop(conns);
        let _ = tx.send(attach_id(&Json::Obj(m), &id));
        return;
    }
    // Admission (shutdown is exempt: an overloaded server must still be
    // stoppable).
    if cmd != "shutdown" {
        let stopping = shared.stop.load(Ordering::SeqCst);
        let admitted = !stopping && {
            let q = shared.queue.lock().unwrap();
            shared.inflight.load(Ordering::SeqCst) < shared.cfg.max_inflight
                && q.len() < shared.cfg.queue_cap
        };
        if !admitted {
            shared.stats.rejected();
            cstats.rejections.fetch_add(1, Ordering::Relaxed);
            let (code, why) = if stopping {
                ("shutdown", "server is shutting down".to_string())
            } else {
                (
                    "overloaded",
                    format!(
                        "server at capacity ({} in-flight cap, {} queue cap); retry later",
                        shared.cfg.max_inflight, shared.cfg.queue_cap
                    ),
                )
            };
            let _ = tx.send(attach_id(&error_json(&cmd, &why, Some(code)), &id));
            return;
        }
    }
    shared.inflight.fetch_add(1, Ordering::SeqCst);
    let work = Work { cmd, id, line, reply: tx.clone() };
    if is_stream {
        // A blocking event stream would park a dispatcher for a whole
        // job; give it its own thread (it still counts against the
        // in-flight cap — streams hold resources too).
        let s = shared.clone();
        shared.register_thread(std::thread::spawn(move || process(&s, work)));
    } else {
        shared.queue.lock().unwrap().push_back(work);
        shared.queue_cond.notify_one();
    }
}

fn dispatcher_loop(shared: &Arc<ServerShared>) {
    loop {
        let work = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(w) = q.pop_front() {
                    break Some(w);
                }
                // Exit only on stop AND empty: admitted work drains.
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.queue_cond.wait(q).unwrap();
            }
        };
        match work {
            Some(w) => process(shared, w),
            None => return,
        }
    }
}

/// Run one admitted request to completion and answer on its
/// connection.  `infer` detours through the micro-batcher; everything
/// else reuses the stdio dispatcher verbatim, with a line-splitting
/// adapter re-tagging each response line with the request id.
fn process(shared: &Arc<ServerShared>, work: Work) {
    let mut out = LineWriter { id: work.id.clone(), tx: work.reply.clone(), buf: Vec::new() };
    let flow = if work.cmd == "infer" {
        if let Ok(req) = Json::parse(&work.line) {
            let response = match proto::parse_infer_frame(&req) {
                Ok((ireq, artifacts, job)) => {
                    let model = ireq.model.clone();
                    let key = BatchKey {
                        artifacts,
                        model: model.clone(),
                        engine: ireq.engine,
                        precision: ireq.precision,
                        job,
                    };
                    match shared.batcher.submit(key, ireq) {
                        Ok(infer_out) => proto::infer_response(&model, &infer_out),
                        Err(e) => proto::error_line("infer", &e),
                    }
                }
                Err(e) => proto::error_line("infer", &e),
            };
            let _ = writeln!(out, "{response}");
            Flow::Continue
        } else {
            proto::handle_line(&shared.svc, &work.line, &mut out).unwrap_or(Flow::Continue)
        }
    } else {
        // LineWriter cannot fail, so the io::Result is vacuous here.
        proto::handle_line(&shared.svc, &work.line, &mut out).unwrap_or(Flow::Continue)
    };
    shared.inflight.fetch_sub(1, Ordering::SeqCst);
    if flow == Flow::Shutdown {
        shared.trigger_stop();
    }
}

/// `Write` adapter between the line-oriented protocol dispatcher and
/// the framed transport: buffers bytes, and on every completed line
/// re-parses it, inserts the request `"id"`, and ships it to the
/// connection's writer.  This is what lets `handle_line` — including
/// its streamed `events` lines — run verbatim over sockets.
struct LineWriter {
    id: Option<Json>,
    tx: Sender<String>,
    buf: Vec<u8>,
}

impl Write for LineWriter {
    fn write(&mut self, bytes: &[u8]) -> io::Result<usize> {
        self.buf.extend_from_slice(bytes);
        while let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = self.buf.drain(..=pos).collect();
            let text = String::from_utf8_lossy(&line);
            let text = text.trim();
            if text.is_empty() {
                continue;
            }
            // A send failure means the peer is gone; the work still
            // runs to completion (its job-side effects are real), the
            // response is simply undeliverable.
            let _ = self.tx.send(attach_line_id(text, &self.id));
        }
        Ok(bytes.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Insert the request `"id"` into one serialized response line.
fn attach_line_id(line: &str, id: &Option<Json>) -> String {
    let Some(id) = id else {
        return line.to_string();
    };
    match Json::parse(line) {
        Ok(Json::Obj(mut m)) => {
            m.insert("id".to_string(), id.clone());
            Json::Obj(m).to_string()
        }
        // Every protocol response is a JSON object; anything else is
        // passed through untagged rather than corrupted.
        _ => line.to_string(),
    }
}

fn attach_id(response: &Json, id: &Option<Json>) -> String {
    attach_line_id(&response.to_string(), id)
}

/// An in-band error response, optionally machine-tagged (`"code"`:
/// `"overloaded"` at admission, `"shutdown"` while stopping).
fn error_json(cmd: &str, error: &str, code: Option<&str>) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(false)),
        ("cmd", json::str(cmd)),
        ("error", json::str(error)),
    ];
    if let Some(code) = code {
        fields.push(("code", json::str(code)));
    }
    json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_reattaches_to_response_lines_verbatim() {
        let id = Some(Json::Str("req-77".to_string()));
        let tagged = attach_line_id(r#"{"ok":true,"cmd":"status"}"#, &id);
        let parsed = Json::parse(&tagged).unwrap();
        assert_eq!(parsed.get("id").and_then(|v| v.as_str()), Some("req-77"));
        assert_eq!(parsed.get("ok"), Some(&Json::Bool(true)));
        // Numeric ids survive too, and absent ids change nothing.
        let tagged = attach_line_id(r#"{"ok":true}"#, &Some(json::num(42.0)));
        assert_eq!(Json::parse(&tagged).unwrap().get("id").and_then(|v| v.as_usize()), Some(42));
        assert_eq!(attach_line_id(r#"{"ok":true}"#, &None), r#"{"ok":true}"#);
    }

    #[test]
    fn line_writer_splits_and_tags_streamed_lines() {
        let (tx, rx) = mpsc::channel();
        let mut lw = LineWriter { id: Some(json::num(7.0)), tx, buf: Vec::new() };
        // Two lines delivered across split writes, exactly as the
        // events streamer emits them.
        lw.write_all(b"{\"ok\":true,\"event\":\"started\"}\n{\"ok\":").unwrap();
        lw.write_all(b"true,\"event\":\"done\"}\n").unwrap();
        drop(lw);
        let lines: Vec<String> = rx.iter().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.get("id").and_then(|i| i.as_usize()), Some(7));
        }
        assert!(lines[1].contains("done"));
    }

    #[test]
    fn error_json_carries_the_code_tag() {
        let e = error_json("infer", "server at capacity", Some("overloaded"));
        assert_eq!(e.get("code").and_then(|v| v.as_str()), Some("overloaded"));
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
        assert!(error_json("x", "y", None).get("code").is_none());
    }
}

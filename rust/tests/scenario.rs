//! Scenario-harness integration tests (tier-1, artifact-free): the
//! adversarial workload driver over the serving core.
//!
//! What is pinned:
//! * the workload generator is deterministic and its traces round-trip
//!   bit-exactly through the JSON-lines trace file format;
//! * a quick soak with EVERY fault class armed (cancel storms, worker
//!   death, eviction-under-use, malformed frames) completes with ZERO
//!   invariant violations, and two runs of the same seed replay the
//!   identical event sequence;
//! * a recorded trace replays to the same workload (record → replay
//!   equivalence);
//! * a cancel storm against queued AND running jobs leaves exactly one
//!   terminal state per job and the service drains to idle — the
//!   "exactly one party writes each terminal state" invariant under
//!   contention (satellite: concurrency regression);
//! * hammering one variant from many threads at f32/bf16/i8
//!   simultaneously loads each (variant, precision) cache entry exactly
//!   once, with predictions bit-identical to sequential (satellite:
//!   quantize-on-load never duplicates or diverges).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use wasi_train::coordinator::FinetuneConfig;
use wasi_train::engine::demo::{write_demo_artifacts, DemoConfig};
use wasi_train::engine::EngineKind;
use wasi_train::precision::Precision;
use wasi_train::scenario::{
    generate, read_trace, run_soak, write_trace, FaultPlan, GeneratorConfig, SoakConfig,
};
use wasi_train::serve::{runner, InferRequest, JobEvent, JobSpec, PoolEntry, Service, ServiceConfig};

fn demo_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wasi_scenario_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
    dir
}

#[test]
fn generator_is_deterministic_and_traces_round_trip() {
    let variants = vec!["vit_demo_wasi_eps80".to_string(), "vit_demo_vanilla".to_string()];
    let mut gcfg = GeneratorConfig::new(variants, 200, 42);
    gcfg.evict = true;
    gcfg.malformed = true;

    let t1 = generate(&gcfg);
    let t2 = generate(&gcfg);
    assert_eq!(t1, t2, "same seed must generate the identical trace");
    assert_eq!(t1.len(), 200);

    // Different seed, different workload.
    let mut other = gcfg.clone();
    other.seed = 43;
    assert_ne!(t1, generate(&other));

    // File round-trip is exact (the reproducibility contract: a failing
    // soak's recorded trace replays the same workload anywhere).
    let dir = std::env::temp_dir().join("wasi_scenario_it_trace");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("trace.jsonl");
    write_trace(&path, &t1).unwrap();
    let back = read_trace(&path).unwrap();
    assert_eq!(t1, back, "trace file round-trip must be lossless");
}

/// The CI acceptance criterion: a bounded soak with every fault class
/// armed completes with zero invariant violations, and the same seed
/// replays the identical event sequence.
#[test]
fn quick_soak_with_all_faults_is_clean_and_deterministic() {
    let dir = demo_dir("soak_all");
    let mut cfg = SoakConfig::quick(&dir);
    cfg.events = 60;
    cfg.faults = FaultPlan::all();
    cfg.trace_out = Some(dir.join("trace1.jsonl"));

    let r1 = run_soak(&cfg).unwrap();
    assert!(r1.violations.is_empty(), "soak run 1 violations: {:?}", r1.violations);
    assert_eq!(r1.events_replayed, 60, "quick soak must not hit the wallclock cap");
    assert!(!r1.truncated);
    assert!(r1.ops.submits > 0 && r1.ops.infers > 0, "mixed workload expected: {:?}", r1.ops);
    assert!(r1.jobs.total() == r1.ops.submits);

    cfg.trace_out = Some(dir.join("trace2.jsonl"));
    let r2 = run_soak(&cfg).unwrap();
    assert!(r2.violations.is_empty(), "soak run 2 violations: {:?}", r2.violations);

    // Identical event sequence: the recorded traces are byte-identical,
    // and the replayed op mix matches exactly.
    let t1 = std::fs::read(dir.join("trace1.jsonl")).unwrap();
    let t2 = std::fs::read(dir.join("trace2.jsonl")).unwrap();
    assert_eq!(t1, t2, "same seed must record byte-identical traces");
    assert_eq!(format!("{:?}", r1.ops), format!("{:?}", r2.ops));
    assert_eq!(r1.events_replayed, r2.events_replayed);

    // Telemetry is populated: depth series sampled per event, latency
    // stats carry one sample per finished unit of work.
    assert_eq!(r1.queue_depth.len(), r1.events_replayed);
    assert_eq!(r1.submit_to_done.count(), r1.jobs.done);
    assert_eq!(r1.infer_roundtrip.count(), r1.ops.infers);
}

/// Record → replay equivalence: replaying a recorded trace executes the
/// same workload as the generating run.
#[test]
fn recorded_trace_replays_identically() {
    let dir = demo_dir("soak_replay");
    let trace = dir.join("recorded.jsonl");

    let mut cfg = SoakConfig::quick(&dir);
    cfg.events = 40;
    cfg.trace_out = Some(trace.clone());
    let recorded = run_soak(&cfg).unwrap();
    assert!(recorded.violations.is_empty(), "{:?}", recorded.violations);

    let mut replay_cfg = SoakConfig::quick(&dir);
    replay_cfg.trace_in = Some(trace);
    replay_cfg.events = 0; // ignored when replaying
    let replayed = run_soak(&replay_cfg).unwrap();
    assert!(replayed.violations.is_empty(), "{:?}", replayed.violations);
    assert_eq!(replayed.events_total, recorded.events_total);
    assert_eq!(format!("{:?}", replayed.ops), format!("{:?}", recorded.ops));
}

/// Satellite (concurrency regression): a cancel storm from many threads
/// against a mix of queued and running jobs must leave EXACTLY one
/// terminal state per job, and the service must drain to idle and stay
/// functional.
#[test]
fn cancel_storm_leaves_exactly_one_terminal_per_job() {
    let dir = demo_dir("storm");
    let svc = Service::start(ServiceConfig::new(dir).with_workers(2)).unwrap();
    let models = ["vit_demo_wasi_eps80", "vit_demo_vanilla"];

    // 8 jobs × 30 steps: with 2 workers the first two start Running and
    // six sit Queued when the storm lands.
    let jobs: Vec<_> = (0..8)
        .map(|j| {
            let cfg = FinetuneConfig::builder()
                .model(models[j % 2])
                .samples(32)
                .steps(30)
                .seed(100 + j as u64)
                .lr0(0.1)
                .engine(EngineKind::Native)
                .build();
            let id = svc.submit(JobSpec::new(cfg)).unwrap();
            (id, svc.take_events(id).unwrap())
        })
        .collect();
    let ids: Vec<_> = jobs.iter().map(|(id, _)| *id).collect();

    // The storm: 4 threads hammering cancel on every job, repeatedly —
    // every cancel path (dequeue-a-Queued-job, flag-a-Running-job,
    // cancel-an-already-terminal-job) races against the workers and
    // against the other cancellers.
    let cancels_hit = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for t in 0..4 {
            let svc = &svc;
            let ids = &ids;
            let cancels_hit = &cancels_hit;
            s.spawn(move || {
                for pass in 0..3 {
                    for (i, id) in ids.iter().enumerate() {
                        // Stagger the storm across threads/passes so
                        // cancels interleave with job starts.
                        if (i + t + pass) % 2 == 0 {
                            std::thread::yield_now();
                        }
                        if svc.cancel(*id) {
                            cancels_hit.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    assert!(cancels_hit.load(Ordering::Relaxed) > 0, "storm never landed a cancel");

    // Exactly one terminal event per job stream, then disconnect.
    for (id, rx) in jobs {
        let mut terminals = 0;
        for ev in rx.iter() {
            match ev {
                JobEvent::Done { .. } => terminals += 1,
                JobEvent::Failed { error, .. } => {
                    terminals += 1;
                    assert!(
                        error.contains("cancelled"),
                        "storm-failed job {id} must fail as cancelled, got {error:?}"
                    );
                }
                _ => {}
            }
        }
        assert_eq!(terminals, 1, "job {id} emitted {terminals} terminal events");
        assert!(
            svc.status(id).map(|st| st.is_terminal()).unwrap_or(false),
            "job {id} not terminal after its stream closed"
        );
    }

    // Drained: nothing queued, nothing running.
    assert_eq!(svc.queue_depth(), 0);
    assert_eq!(svc.running_count(), 0);

    // And the service still works: a fresh job runs to Done.
    let cfg = FinetuneConfig::builder()
        .model(models[0])
        .samples(32)
        .steps(3)
        .seed(999)
        .lr0(0.1)
        .engine(EngineKind::Native)
        .build();
    let id = svc.submit(JobSpec::new(cfg)).unwrap();
    svc.wait(id).expect("service must stay functional after the storm");
    svc.shutdown();
}

/// Satellite (pool cache): hammering ONE variant from many threads
/// requesting f32/bf16/i8 simultaneously loads each (variant,
/// precision) entry exactly once, and every thread's predictions are
/// bit-identical to a sequential run.
#[test]
fn concurrent_mixed_precision_infer_loads_each_key_once() {
    let dir = demo_dir("pool_hammer");
    let model = "vit_demo_wasi_eps80";
    let precisions = [Precision::F32, Precision::Bf16, Precision::I8];
    let req = |p: Precision| InferRequest {
        model: model.to_string(),
        engine: EngineKind::Native,
        precision: p,
        seed: 233,
        x: None,
    };

    // Sequential reference on a fresh pool entry.
    let entry = PoolEntry::open(dir.to_str().unwrap()).unwrap();
    let sequential: Vec<Vec<usize>> = precisions
        .iter()
        .map(|p| runner::run_infer(&entry, &req(*p), None).unwrap().preds)
        .collect();
    assert_eq!(entry.infer_loads(), 3, "sequential run must load each precision once");

    // 12 threads (4 per precision) racing on a second fresh entry.
    let entry2 = PoolEntry::open(dir.to_str().unwrap()).unwrap();
    let results: Vec<(usize, Vec<usize>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..12)
            .map(|t| {
                let entry2 = &entry2;
                let req = &req;
                s.spawn(move || {
                    let pi = t % 3;
                    (pi, runner::run_infer(entry2, &req(precisions[pi]), None).unwrap().preds)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(
        entry2.infer_loads(),
        3,
        "concurrent run must load each (variant, precision) exactly once"
    );
    assert_eq!(entry2.cached_infer_engines(), 3);
    assert_eq!(entry2.infer_evictions(), 0);
    for (pi, preds) in results {
        assert_eq!(
            preds, sequential[pi],
            "concurrent {} predictions diverged from sequential",
            precisions[pi]
        );
    }
}

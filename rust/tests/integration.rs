//! Integration tests over the full three-layer stack: rust loads the
//! AOT-compiled HLO artifacts and checks training/inference semantics and
//! cross-engine numerics (Pallas kernel vs jnp reference, executed through
//! PJRT from rust).
//!
//! These tests require `make artifacts`; they skip (pass with a notice)
//! when the artifacts directory is absent so `cargo test` stays green on
//! a fresh checkout.  Tests that execute full model HLO additionally
//! require a live PJRT backend (`--features pjrt` with the real `xla`
//! crate) and skip under the native fallback runtime; the kernel
//! cross-check and the manifest-only tests run in every configuration.

use wasi_train::coordinator::{CosineSchedule, FinetuneConfig, Session};
use wasi_train::data::rng::Pcg64;
use wasi_train::data::synth::VisionTask;
use wasi_train::runtime::{InferStep, Manifest, Runtime, TrainStep};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("integration: artifacts not built; skipping");
        None
    }
}

/// A runtime able to execute model train/infer HLO, or None (skip).
fn model_runtime() -> Option<Runtime> {
    let rt = Runtime::cpu().unwrap();
    if rt.can_execute_hlo() {
        Some(rt)
    } else {
        eprintln!("integration: model HLO execution needs a live PJRT backend; skipping");
        None
    }
}

#[test]
fn wasi_train_step_converges() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = model_runtime() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_wasi_eps80").unwrap();
    let mut step = TrainStep::load(&rt, entry).unwrap();
    let mut task = VisionTask::new("t", entry.classes, 32, 0.7, 8, 233);
    let sched = CosineSchedule::paper_default(20);
    let mut losses = Vec::new();
    for s in 0..20 {
        let (x, y, _) = task.batch_onehot(entry.batch);
        let out = step.step(&x, &y, sched.lr(s)).unwrap();
        assert!(out.loss.is_finite(), "loss must stay finite");
        losses.push(out.loss);
    }
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[15..].iter().sum::<f32>() / 5.0;
    assert!(
        tail < head,
        "loss should fall: head {head} vs tail {tail} ({losses:?})"
    );
}

#[test]
fn state_vector_evolves_and_params_change() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = model_runtime() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_wasi_eps80").unwrap();
    let mut step = TrainStep::load(&rt, entry).unwrap();
    let p0 = step.params.clone();
    let s0 = step.state.clone();
    let mut task = VisionTask::new("t", entry.classes, 32, 0.7, 8, 1);
    let (x, y, _) = task.batch_onehot(entry.batch);
    step.step(&x, &y, 0.05).unwrap();
    assert_ne!(step.params, p0, "params must update");
    assert_ne!(step.state, s0, "ASI warm-start state must update");
    assert_eq!(step.params.len(), entry.params_len);
    assert_eq!(step.state.len(), entry.state_len);
}

#[test]
fn infer_is_deterministic_and_matches_classes() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = model_runtime() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    for name in ["vit_vanilla", "vit_wasi_eps80"] {
        let entry = manifest.model(name).unwrap();
        let step = TrainStep::load(&rt, entry).unwrap();
        let infer = InferStep::load(&rt, entry).unwrap();
        let mut task = VisionTask::new("t", entry.classes, 32, 0.7, 8, 2);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let a = infer.infer(&step.params, &x).unwrap();
        let b = infer.infer(&step.params, &x).unwrap();
        assert_eq!(a, b, "{name}: inference must be deterministic");
        assert_eq!(a.len(), entry.batch * entry.classes);
    }
}

#[test]
fn pallas_kernel_matches_jnp_reference_through_pjrt() {
    // The L1 cross-check executed from L3: the Pallas lowrank kernel HLO
    // and the pure-jnp reference HLO must agree bitwise-closely on the
    // same inputs.  Only PJRT makes this a true cross-check (it executes
    // the two distinct HLO programs); under the native backend both
    // artifacts dispatch to the same reference math, so the run reduces
    // to a smoke test of the native kernel path.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let (Some(pk), Some(rk)) = (
        manifest.kernels.get("lowrank_pallas"),
        manifest.kernels.get("lowrank_ref"),
    ) else {
        eprintln!("kernel artifacts missing; skipping");
        return;
    };
    let mut rng = Pcg64::new(7);
    let shapes = &pk.shapes;
    let x_shape = shapes.get("x").unwrap().clone();
    let l_shape = shapes.get("l").unwrap().clone();
    let r_shape = shapes.get("r").unwrap().clone();
    let x: Vec<f32> = rng.normal_vec(x_shape.iter().product());
    let l: Vec<f32> = rng.normal_vec(l_shape.iter().product());
    let r: Vec<f32> = rng.normal_vec(r_shape.iter().product());
    let inputs: Vec<(&[f32], &[usize])> = vec![
        (&x, x_shape.as_slice()),
        (&l, l_shape.as_slice()),
        (&r, r_shape.as_slice()),
    ];
    let pallas = rt.load(&pk.hlo).unwrap().run_f32(&inputs).unwrap();
    let reference = rt.load(&rk.hlo).unwrap().run_f32(&inputs).unwrap();
    assert_eq!(pallas.len(), reference.len());
    let scale = reference[0]
        .iter()
        .fold(1e-6f32, |m, v| m.max(v.abs()));
    for (a, b) in pallas[0].iter().zip(&reference[0]) {
        assert!(
            (a - b).abs() <= 1e-4 * scale,
            "pallas {a} vs ref {b} (scale {scale})"
        );
    }
}

#[test]
fn kernel_variant_trains_with_pallas_in_graph() {
    // The vit_wasi_kernel_eps80 artifact has the Pallas kernels lowered
    // INTO the train step — prove the composed stack executes and learns.
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = model_runtime() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let Ok(entry) = manifest.model("vit_wasi_kernel_eps80") else {
        eprintln!("kernel variant not built; skipping");
        return;
    };
    let mut step = TrainStep::load(&rt, entry).unwrap();
    let mut task = VisionTask::new("t", entry.classes, 32, 0.7, 8, 3);
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..6 {
        let (x, y, _) = task.batch_onehot(entry.batch);
        let out = step.step(&x, &y, 0.05).unwrap();
        assert!(out.loss.is_finite());
        first.get_or_insert(out.loss);
        last = out.loss;
    }
    assert!(last < first.unwrap() * 1.5, "kernel variant must not diverge");
}

#[test]
fn session_finetune_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    if model_runtime().is_none() {
        return;
    }
    let session = Session::open(dir.to_str().unwrap()).unwrap();
    let report = session
        .finetune(&FinetuneConfig {
            model: "vit_wasi_eps80".into(),
            dataset: "cifar10-like".into(),
            samples: 128,
            steps: 12,
            seed: 233,
            verbose: false,
            ..FinetuneConfig::default()
        })
        .unwrap();
    assert!(report.final_loss.is_finite());
    assert!(report.val_accuracy >= 0.0 && report.val_accuracy <= 1.0);
    assert!(report.memory.total() > 0);
    assert!(!report.loss_curve.is_empty());
}

#[test]
fn wasi_memory_below_vanilla_across_eps() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let vanilla = manifest.model("vit_vanilla").unwrap();
    let v_weights = vanilla.params_len;
    let mut prev_mem = 0usize;
    for entry in manifest.vit_wasi_variants() {
        let mem = entry.params_len + entry.state_len;
        assert!(
            mem < v_weights,
            "{}: factored params+state {} should be below dense {}",
            entry.name,
            mem,
            v_weights
        );
        assert!(mem >= prev_mem, "memory should grow with eps");
        prev_mem = mem;
    }
}

#[test]
fn perplexity_table_drives_dp_planner() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let Some(table) = &manifest.perplexity else {
        eprintln!("no perplexity table; skipping");
        return;
    };
    table.validate().unwrap();
    // WASI uniform plans: higher eps -> more memory, less perplexity.
    let mut prev_mem = 0usize;
    let mut prev_ppl = f64::INFINITY;
    for &eps in &table.eps_grid {
        let plan = wasi_train::wasi::rank_select::plan_ranks_wasi(table, eps).unwrap();
        assert!(plan.total_memory >= prev_mem);
        assert!(plan.total_perplexity <= prev_ppl + 1e-9);
        prev_mem = plan.total_memory;
        prev_ppl = plan.total_perplexity;
    }
    // Budgeted DP at the eps=0.9 memory point (plus one discretization
    // cell per layer of slack — the DP ceils item sizes to keep its
    // budget guarantee hard) should do at least as well as uniform 0.9.
    let uniform = wasi_train::wasi::rank_select::plan_ranks_wasi(table, 0.9).unwrap();
    let grid = 4096usize;
    let slack = (uniform.total_memory / grid + 1) * table.layers.len();
    let dp = wasi_train::wasi::rank_select::plan_ranks(
        table, uniform.total_memory + slack, grid)
        .unwrap();
    assert!(
        dp.total_perplexity <= uniform.total_perplexity + 1e-9,
        "dp {} vs uniform {}",
        dp.total_perplexity,
        uniform.total_perplexity
    );
}

//! Integration pins for the precision subsystem (DESIGN.md
//! §Precision): int8-vs-f32 top-1 agreement on the demo artifact, bf16
//! weight-storage invariants through training, and reduced-precision
//! serving through the job service protocol.

use std::path::PathBuf;

use wasi_train::data::synth::VisionTask;
use wasi_train::engine::demo::{write_demo_artifacts, DemoConfig};
use wasi_train::engine::{InferEngine, NativeInferEngine, NativeModelEngine, TrainEngine};
use wasi_train::precision::{bf16_to_f32, dequantize_i8, f32_to_bf16, quantize_i8, Precision};
use wasi_train::runtime::Manifest;
use wasi_train::serve::{serve_lines, Service, ServiceConfig};
use wasi_train::util::json::Json;

fn demo_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wasi_precision_test_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
    dir
}

/// The agreement pin, margin-aware: quantized inference must
/// reproduce the f32 engine's top-1 predictions on every sample with
/// a decisive logit margin.  A flip is mathematically possible only
/// when the f32 top-2 gap is within twice the quantization drift, so
/// the pin (a) bounds the drift itself relative to the logit scale,
/// (b) rejects any flip on a decisively-margined sample, and (c)
/// bounds how many near-tie samples may flip at all.  (The demo net
/// is untrained, so a few near-random margins in the probe batch are
/// expected; an EXACT-equality pin would gate on coin flips.)
fn assert_top1_agreement(
    f32_logits: &[f32],
    q_logits: &[f32],
    classes: usize,
    max_flips: usize,
    max_rel_drift: f32,
    label: &str,
) {
    let drift = f32_logits
        .iter()
        .zip(q_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let scale = f32_logits.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    assert!(
        drift <= max_rel_drift * scale,
        "{label}: logit drift {drift} exceeds {max_rel_drift} of logit scale {scale}"
    );
    let f32_preds = wasi_train::engine::ops::argmax_rows(f32_logits, classes);
    let q_preds = wasi_train::engine::ops::argmax_rows(q_logits, classes);
    let mut flips = 0usize;
    for (row, (pf, pq)) in f32_preds.iter().zip(&q_preds).enumerate() {
        if pf == pq {
            continue;
        }
        let base = &f32_logits[row * classes..(row + 1) * classes];
        let gap = (base[*pf] - base[*pq]).abs();
        assert!(
            gap <= 2.0 * drift,
            "{label}: sample {row} flipped a DECISIVE prediction (f32 gap {gap}, drift {drift})"
        );
        flips += 1;
    }
    assert!(
        flips <= max_flips,
        "{label}: {flips} near-tie flips exceed the allowed {max_flips} \
         (preds {f32_preds:?} vs {q_preds:?})"
    );
}

#[test]
fn int8_top1_predictions_match_f32_on_demo_artifact() {
    let dir = demo_dir("agree");
    let manifest = Manifest::load(&dir).unwrap();
    for model in ["vit_demo_vanilla", "vit_demo_wasi_eps80"] {
        let entry = manifest.model(model).unwrap();
        let f32_engine = NativeInferEngine::load(entry).unwrap();
        let i8_engine = NativeInferEngine::load_quantized(entry, Precision::I8).unwrap();
        assert_eq!(i8_engine.precision(), Precision::I8);
        let params = entry.load_params().unwrap();
        let mut task = VisionTask::new("agree", entry.classes, 16, 0.5, 4, 233);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let f32_logits = f32_engine.infer(&params, &x).unwrap();
        let i8_logits = i8_engine.infer_quantized(&x).unwrap();
        assert_top1_agreement(&f32_logits, &i8_logits, entry.classes, 2, 0.15, model);
    }
}

/// The TRUE-integer int8 path vs the old dequantizing route: the deq
/// GEMM was pinned bitwise to f32 inference over round-tripped
/// (dequantized) weights, so that reconstruction IS the old path.  The
/// integer path runs the same quantized weights with exact i8×i8→i32
/// arithmetic; the only difference is the per-row activation
/// round-trip, bounded by `s_row/2` per element (the kernel-level
/// bound test in `linalg::kernels` enforces the formula; this pin
/// checks it stays prediction-preserving end-to-end on the demo
/// artifact).
#[test]
fn int8_integer_path_tracks_dequantizing_path_on_demo_artifact() {
    let dir = demo_dir("intdeq");
    let manifest = Manifest::load(&dir).unwrap();
    for model in ["vit_demo_vanilla", "vit_demo_wasi_eps80"] {
        let entry = manifest.model(model).unwrap();
        let params = entry.load_params().unwrap();
        let mut roundtripped = params.clone();
        for spec in &entry.param_spec {
            let is_gemm = spec.shape.len() == 2
                && (spec.name.ends_with(".w")
                    || spec.name.ends_with(".l")
                    || spec.name.ends_with(".r"));
            if is_gemm {
                let range = spec.offset..spec.offset + spec.numel();
                let (q, scale) = quantize_i8(&params[range.clone()]);
                roundtripped[range].copy_from_slice(&dequantize_i8(&q, scale));
            }
        }
        let f32_engine = NativeInferEngine::load(entry).unwrap();
        let i8_engine = NativeInferEngine::load_quantized(entry, Precision::I8).unwrap();
        let mut task = VisionTask::new("intdeq", entry.classes, 16, 0.5, 4, 77);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let deq_logits = f32_engine.infer(&roundtripped, &x).unwrap();
        let int_logits = i8_engine.infer_quantized(&x).unwrap();
        assert_top1_agreement(&deq_logits, &int_logits, entry.classes, 2, 0.15, model);
    }
}

/// bf16 drift is an order of magnitude tighter than int8's, so at
/// most one near-tie sample may move.
#[test]
fn bf16_top1_predictions_match_f32_on_demo_artifact() {
    let dir = demo_dir("agree16");
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_demo_wasi_eps80").unwrap();
    let f32_engine = NativeInferEngine::load(entry).unwrap();
    let bf16_engine = NativeInferEngine::load_quantized(entry, Precision::Bf16).unwrap();
    let params = entry.load_params().unwrap();
    let mut task = VisionTask::new("agree16", entry.classes, 16, 0.5, 4, 41);
    let (x, _, _) = task.batch_onehot(entry.batch);
    let f32_logits = f32_engine.infer(&params, &x).unwrap();
    let bf16_logits = bf16_engine.infer_quantized(&x).unwrap();
    assert_top1_agreement(&f32_logits, &bf16_logits, entry.classes, 1, 0.05, "bf16");
}

fn all_bf16_representable(data: &[f32]) -> bool {
    data.iter().all(|&v| bf16_to_f32(f32_to_bf16(v)).to_bits() == v.to_bits())
}

/// bf16 weight storage through training: every stored parameter is
/// exactly bf16-representable after load, after each step, and after a
/// restore — and the run still descends.
#[test]
fn bf16_training_keeps_weights_bf16_representable_and_descends() {
    let dir = demo_dir("train16");
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_demo_wasi_eps80").unwrap();
    let mut eng = NativeModelEngine::load_with(entry, Precision::Bf16).unwrap();
    assert_eq!(eng.precision(), Precision::Bf16);
    assert!(all_bf16_representable(eng.params()), "load must round to bf16");
    // The f32 engine's params are NOT all bf16-representable — the
    // invariant below is not vacuous.
    let f32_eng = NativeModelEngine::load(entry).unwrap();
    assert!(!all_bf16_representable(f32_eng.params()));

    let mut task = VisionTask::new("t16", entry.classes, 16, 0.5, 4, 233);
    let (x, y, _) = task.batch_onehot(entry.batch);
    let mut losses = Vec::new();
    for _ in 0..16 {
        let out = eng.step(&x, &y, 0.1).unwrap();
        assert!(out.loss.is_finite());
        losses.push(out.loss);
    }
    assert!(all_bf16_representable(eng.params()), "steps must re-round to bf16");
    let head: f32 = losses[..4].iter().sum::<f32>() / 4.0;
    let tail: f32 = losses[12..].iter().sum::<f32>() / 4.0;
    assert!(tail < head * 0.9, "bf16 training should still descend ({losses:?})");

    // Restoring raw f32 values into a bf16 engine re-rounds them.
    let state = eng.state().to_vec();
    eng.restore(f32_eng.params(), &state).unwrap();
    assert!(all_bf16_representable(eng.params()), "restore must round to bf16");
}

/// int8 is inference-only: the native train engine refuses it.
#[test]
fn i8_training_is_refused() {
    let dir = demo_dir("refuse8");
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_demo_vanilla").unwrap();
    let err = NativeModelEngine::load_with(entry, Precision::I8).unwrap_err();
    assert!(format!("{err:#}").contains("inference-only"), "{err:#}");
}

/// Reduced precision through the serve protocol: a bf16 job trains to
/// Done, int8 pool inference answers with its precision echoed, and
/// int8 inference against the finished job's personalized params works
/// (packed per request).
#[test]
fn serve_protocol_supports_precision_jobs_and_quantized_infer() {
    let dir = demo_dir("serve");
    let svc = Service::start(ServiceConfig::new(dir).with_workers(1)).unwrap();
    let input = [
        r#"{"cmd":"submit","model":"vit_demo_wasi_eps80","steps":4,"samples":32,"engine":"native","precision":"bf16"}"#,
        r#"{"cmd":"events","job":1,"wait":true}"#,
        r#"{"cmd":"infer","model":"vit_demo_vanilla","seed":7,"precision":"i8"}"#,
        r#"{"cmd":"infer","model":"vit_demo_wasi_eps80","job":1,"precision":"i8"}"#,
        r#"{"cmd":"infer","model":"vit_demo_vanilla","precision":"f16"}"#,
        r#"{"cmd":"shutdown"}"#,
    ]
    .join("\n");
    let mut out = Vec::new();
    serve_lines(&svc, input.as_bytes(), &mut out).unwrap();
    svc.shutdown();
    let responses: Vec<Json> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).expect("every response line is JSON"))
        .collect();

    let done: Vec<&Json> = responses
        .iter()
        .filter(|r| r.get("event").and_then(|v| v.as_str()) == Some("done"))
        .collect();
    assert_eq!(done.len(), 1, "{responses:?}");
    let report = done[0].get("report").unwrap();
    assert_eq!(report.get("precision").and_then(|v| v.as_str()), Some("bf16"));

    let infers: Vec<&Json> = responses
        .iter()
        .filter(|r| r.get("cmd").and_then(|v| v.as_str()) == Some("infer"))
        .collect();
    assert_eq!(infers.len(), 3, "{responses:?}");
    for ok in &infers[..2] {
        assert_eq!(ok.get("ok"), Some(&Json::Bool(true)), "{ok:?}");
        assert_eq!(ok.get("precision").and_then(|v| v.as_str()), Some("i8"));
        assert!(ok
            .get("preds")
            .and_then(|v| v.as_arr())
            .map(|a| !a.is_empty())
            .unwrap_or(false));
    }
    // Unknown precision is an in-band request error, not a crash.
    assert_eq!(infers[2].get("ok"), Some(&Json::Bool(false)));
    assert!(
        infers[2]
            .get("error")
            .and_then(|v| v.as_str())
            .map(|e| e.contains("unknown precision"))
            .unwrap_or(false),
        "{:?}",
        infers[2]
    );
}

//! Socket front-end integration tests (tier-1, artifact-free): the
//! network layer over the pure-rust demo artifacts.
//!
//! What is pinned:
//! * cross-request micro-batching is INVISIBLE in the answers: a
//!   stacked [`Service::infer_batch`] call returns logits bitwise
//!   identical to serving each request alone, at f32, bf16, AND i8,
//!   for pretrained and personalized (job) parameter sources — the
//!   acceptance criterion of the front-end PR;
//! * the [`Batcher`] coalesces only within a [`BatchKey`]: same-key
//!   concurrent requests share exactly one stacked call, requests on
//!   different keys never do;
//! * length-delimited framing over a real loopback socket: request
//!   `"id"`s echo on every response line, garbage inside a well-formed
//!   frame is answered in-band without killing the connection, and a
//!   protocol `shutdown` stops the listener;
//! * admission control degrades overload to a deterministic in-band
//!   `code:"overloaded"` rejection, never an unresponsive socket.

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use wasi_train::coordinator::FinetuneConfig;
use wasi_train::engine::demo::{write_demo_artifacts, DemoConfig};
use wasi_train::engine::EngineKind;
use wasi_train::net::{
    read_frame, serve_listener, write_frame, BatchKey, Batcher, NetConfig, NetStats,
    MAX_FRAME_BYTES,
};
use wasi_train::precision::Precision;
use wasi_train::serve::{InferRequest, JobSpec, Service, ServiceConfig};
use wasi_train::util::json::Json;

fn demo_service(tag: &str, workers: usize) -> Arc<Service> {
    let dir = std::env::temp_dir().join(format!("wasi_net_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
    Arc::new(Service::start(ServiceConfig::new(dir).with_workers(workers)).unwrap())
}

fn req(model: &str, precision: Precision, seed: u64) -> InferRequest {
    InferRequest { model: model.into(), engine: EngineKind::Native, precision, seed, x: None }
}

fn key(precision: Precision) -> BatchKey {
    BatchKey {
        artifacts: None,
        model: "vit_demo_wasi_eps80".into(),
        engine: EngineKind::Native,
        precision,
        job: None,
    }
}

fn bits(logits: &[f32]) -> Vec<u32> {
    logits.iter().map(|v| v.to_bits()).collect()
}

/// The acceptance criterion: a stacked micro-batch answers every
/// request with EXACTLY the bits a solo call produces, at all three
/// serving precisions.
#[test]
fn stacked_infer_is_bit_identical_to_solo_at_every_precision() {
    let svc = demo_service("bitwise", 1);
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        let reqs: Vec<InferRequest> =
            (0..5).map(|i| req("vit_demo_wasi_eps80", precision, 100 + i)).collect();
        let solo: Vec<_> = reqs.iter().map(|r| svc.infer(None, r, None).unwrap()).collect();
        let stacked = svc.infer_batch(None, &reqs, None).unwrap();
        assert_eq!(stacked.len(), solo.len());
        for (s, b) in solo.iter().zip(&stacked) {
            assert_eq!(
                bits(&s.logits),
                bits(&b.logits),
                "{precision} logits diverged under stacking"
            );
            assert!(!b.logits.is_empty(), "{precision} output carries logits");
            assert_eq!(s.preds, b.preds);
            assert_eq!(s.correct, b.correct);
            assert_eq!(s.batch, b.batch);
        }
    }
    svc.shutdown();
}

/// Personalized params (a Done job's weights) ride the same stacked
/// path bit-identically — the batch key pins the job, not just the
/// variant.
#[test]
fn stacked_infer_serves_job_params_bit_identically() {
    let svc = demo_service("bitwise_job", 1);
    let cfg = FinetuneConfig::builder()
        .model("vit_demo_wasi_eps80")
        .samples(32)
        .steps(3)
        .lr0(0.1)
        .engine(EngineKind::Native)
        .build();
    let id = svc.submit(JobSpec::new(cfg)).unwrap();
    svc.wait(id).unwrap();
    let reqs: Vec<InferRequest> =
        (0..4).map(|i| req("vit_demo_wasi_eps80", Precision::F32, 7 + i)).collect();
    let solo: Vec<_> = reqs.iter().map(|r| svc.infer(None, r, Some(id)).unwrap()).collect();
    let stacked = svc.infer_batch(None, &reqs, Some(id)).unwrap();
    for (s, b) in solo.iter().zip(&stacked) {
        assert_eq!(bits(&s.logits), bits(&b.logits), "personalized logits diverged");
        assert_eq!(s.preds, b.preds);
    }
    // The personalized answers really differ from pretrained serving —
    // otherwise the pin above would be vacuous.
    let pre = svc.infer(None, &reqs[0], None).unwrap();
    assert_ne!(bits(&pre.logits), bits(&stacked[0].logits));
    svc.shutdown();
}

/// Requests on DIFFERENT keys (here: precisions) must never share a
/// stacked call, no matter how wide the gather window is.
#[test]
fn batcher_never_coalesces_across_keys() {
    let svc = demo_service("nokey", 2);
    let stats = Arc::new(NetStats::default());
    let batcher = Batcher::new(svc.clone(), 50_000, 4, stats.clone());
    let f32_ref = svc.infer(None, &req("vit_demo_wasi_eps80", Precision::F32, 5), None).unwrap();
    let i8_ref = svc.infer(None, &req("vit_demo_wasi_eps80", Precision::I8, 5), None).unwrap();
    std::thread::scope(|s| {
        let b = &batcher;
        let a = s.spawn(move || {
            b.submit(key(Precision::F32), req("vit_demo_wasi_eps80", Precision::F32, 5)).unwrap()
        });
        let c = s.spawn(move || {
            b.submit(key(Precision::I8), req("vit_demo_wasi_eps80", Precision::I8, 5)).unwrap()
        });
        let out_a = a.join().unwrap();
        let out_c = c.join().unwrap();
        assert_eq!(bits(&out_a.logits), bits(&f32_ref.logits));
        assert_eq!(bits(&out_c.logits), bits(&i8_ref.logits));
    });
    assert_eq!(stats.batches(), 0, "different keys must never share a stacked call");
    assert_eq!(stats.infer_solo(), 2);
    assert_eq!(stats.infer_batched(), 0);
    svc.shutdown();
}

/// Four same-key concurrent requests coalesce into exactly ONE stacked
/// call (the fourth arrival seals the group early — the long window
/// only bounds the wait, the test never sleeps it out), and every
/// caller still gets its own solo-identical answer.
#[test]
fn batcher_coalesces_same_key_into_one_stacked_call() {
    let svc = demo_service("coalesce", 2);
    let stats = Arc::new(NetStats::default());
    let batcher = Batcher::new(svc.clone(), 5_000_000, 4, stats.clone());
    let reqs: Vec<InferRequest> =
        (0..4).map(|i| req("vit_demo_wasi_eps80", Precision::F32, 20 + i)).collect();
    let solo: Vec<_> = reqs.iter().map(|r| svc.infer(None, r, None).unwrap()).collect();
    let outs: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = reqs
            .iter()
            .map(|r| {
                let b = &batcher;
                let r = r.clone();
                s.spawn(move || b.submit(key(Precision::F32), r).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(stats.batches(), 1, "four same-key requests must share one stacked call");
    assert_eq!(stats.infer_batched(), 4);
    assert_eq!(stats.infer_solo(), 0);
    for (s, b) in solo.iter().zip(&outs) {
        assert_eq!(bits(&s.logits), bits(&b.logits), "batched answer diverged from solo");
        assert_eq!(s.preds, b.preds);
    }
    svc.shutdown();
}

fn send_line(stream: &mut TcpStream, line: &str) {
    write_frame(stream, line.as_bytes()).unwrap();
}

fn recv_line(reader: &mut BufReader<TcpStream>) -> Option<Json> {
    let payload = read_frame(reader, MAX_FRAME_BYTES).unwrap()?;
    Some(Json::parse(std::str::from_utf8(&payload).unwrap()).unwrap())
}

/// Framed request/response over a real loopback socket: ids echo on
/// every line (numeric and string), garbage inside a valid frame is
/// answered in-band, and a protocol `shutdown` stops the listener.
#[test]
fn socket_round_trip_echoes_ids_and_survives_garbage() {
    let svc = demo_service("socket", 1);
    let mut handle = serve_listener(
        svc.clone(),
        NetConfig { batch_window_us: 0, max_batch: 1, ..NetConfig::default() },
    )
    .unwrap();
    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let line = r#"{"cmd":"infer","model":"vit_demo_wasi_eps80","seed":3,"precision":"i8","id":42}"#;
    send_line(&mut stream, line);
    let resp = recv_line(&mut reader).unwrap();
    assert_eq!(resp.get("id").and_then(|v| v.as_usize()), Some(42), "{resp:?}");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    assert_eq!(resp.get("precision").and_then(|v| v.as_str()), Some("i8"));
    assert!(resp.get("preds").and_then(|v| v.as_arr()).is_some_and(|a| !a.is_empty()));

    // String ids echo too; `stats` answers inline with net counters.
    send_line(&mut stream, r#"{"cmd":"stats","id":"s-1"}"#);
    let resp = recv_line(&mut reader).unwrap();
    assert_eq!(resp.get("id").and_then(|v| v.as_str()), Some("s-1"));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    let net = resp.get("net").expect("stats carries the net section");
    assert!(net.get("frames_in").and_then(|v| v.as_f64()).is_some_and(|n| n >= 2.0));
    assert!(resp.get("connections").is_some());

    // Garbage inside a well-formed frame: in-band error, live socket.
    send_line(&mut stream, "this is not json");
    let resp = recv_line(&mut reader).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
    send_line(&mut stream, r#"{"cmd":"stats","id":7}"#);
    let resp = recv_line(&mut reader).unwrap();
    assert_eq!(resp.get("id").and_then(|v| v.as_usize()), Some(7));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));

    // A protocol shutdown is acknowledged, then the listener stops.
    send_line(&mut stream, r#"{"cmd":"shutdown","id":9}"#);
    let resp = recv_line(&mut reader).unwrap();
    assert_eq!(resp.get("id").and_then(|v| v.as_usize()), Some(9));
    assert_eq!(resp.get("cmd").and_then(|v| v.as_str()), Some("shutdown"));
    handle.wait_stop();
    handle.shutdown();
    svc.shutdown();
}

/// With the single in-flight slot pinned by a streamed `events`
/// subscription, the next request must be rejected in-band with
/// `code:"overloaded"` — deterministically, not by racing timeouts.
#[test]
fn admission_rejects_overload_in_band() {
    let svc = demo_service("overload", 1);
    let cfg = FinetuneConfig::builder()
        .model("vit_demo_vanilla")
        .samples(32)
        .steps(5000)
        .lr0(0.1)
        .engine(EngineKind::Native)
        .build();
    let job = svc.submit(JobSpec::new(cfg)).unwrap();
    let mut handle = serve_listener(
        svc.clone(),
        NetConfig {
            max_inflight: 1,
            queue_cap: 8,
            batch_window_us: 0,
            max_batch: 1,
            dispatchers: 2,
            ..NetConfig::default()
        },
    )
    .unwrap();

    // Connection A claims the only in-flight slot with a job stream.
    let mut a = TcpStream::connect(handle.addr()).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut ra = BufReader::new(a.try_clone().unwrap());
    send_line(&mut a, &format!(r#"{{"cmd":"events","job":{},"wait":true,"id":"sub"}}"#, job.0));
    let first = recv_line(&mut ra).unwrap();
    assert_eq!(first.get("id").and_then(|v| v.as_str()), Some("sub"), "{first:?}");
    assert_eq!(first.get("event").and_then(|v| v.as_str()), Some("started"));

    // Connection B must be turned away in-band, immediately.
    let mut b = TcpStream::connect(handle.addr()).unwrap();
    b.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut rb = BufReader::new(b.try_clone().unwrap());
    send_line(&mut b, r#"{"cmd":"infer","model":"vit_demo_vanilla","seed":1,"id":"rej"}"#);
    let resp = recv_line(&mut rb).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
    assert_eq!(resp.get("code").and_then(|v| v.as_str()), Some("overloaded"));
    assert_eq!(resp.get("id").and_then(|v| v.as_str()), Some("rej"));

    // ...but `stats` still answers under overload (that is its point).
    send_line(&mut b, r#"{"cmd":"stats","id":"peek"}"#);
    let resp = recv_line(&mut rb).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert!(
        resp.get("net")
            .and_then(|n| n.get("admission_rejections"))
            .and_then(|v| v.as_f64())
            .is_some_and(|n| n >= 1.0),
        "{resp:?}"
    );

    // Cancelling the job terminates A's stream and frees the slot.
    assert!(svc.cancel(job));
    loop {
        let line = recv_line(&mut ra).expect("stream must end with a terminal event");
        match line.get("event").and_then(|v| v.as_str()) {
            Some("failed") => break,
            _ => continue,
        }
    }
    assert!(handle.stats().rejections() >= 1);
    handle.shutdown();
    svc.shutdown();
}

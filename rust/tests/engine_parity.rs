//! Engine-parity tests (tier-1, artifact-free): a tiny "pretrained" ViT
//! fixture is generated in pure rust (`engine::demo`) and fine-tuned
//! through the engine surface, so these run on every build — no Python,
//! no PJRT, no `make artifacts`.
//!
//! What is pinned:
//! * the native full-model engine (the graph-IR executor since the
//!   layer-graph split) completes a real fine-tune end to end through
//!   `Session::finetune` with a decreasing loss — the same trajectory
//!   contract the pre-split engine passed, so the graph rewrite is
//!   pinned against the PR 2 behavior;
//! * the factored (WASI) parameterization's loss trajectory tracks the
//!   dense oracle at a near-lossless ε — the cross-parameterization
//!   numerics check;
//! * training trajectories are bit-identical across kernel-layer thread
//!   counts (the deterministic row partition), so `--threads` is pure
//!   wall-clock;
//! * `--engine auto` falls back to the native engine exactly when the
//!   runtime cannot execute model HLO, and forcing `hlo` there fails
//!   with the documented error;
//! * checkpoint save/restore through the trait is bit-exact;
//! * when a PJRT backend is live, the HLO engine runs the same contract
//!   over the real artifacts (skipped offline).

use std::path::PathBuf;

use wasi_train::coordinator::{Checkpoint, FinetuneConfig, Session};
use wasi_train::data::synth::VisionTask;
use wasi_train::engine::demo::{write_demo_artifacts, DemoConfig};
use wasi_train::engine::{
    infer_engine, train_engine, EngineKind, InferEngine, NativeModelEngine, TrainEngine,
};
use wasi_train::runtime::{Manifest, Runtime};

fn demo_dir(tag: &str, cfg: &DemoConfig) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wasi_parity_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_demo_artifacts(&dir, cfg).unwrap();
    dir
}

#[test]
fn native_engine_full_finetune_end_to_end() {
    let dir = demo_dir("e2e", &DemoConfig::default());
    let session = Session::open(dir.to_str().unwrap()).unwrap();
    let report = session
        .finetune(&FinetuneConfig {
            model: "vit_demo_wasi_eps80".into(),
            dataset: "cifar10-like".into(),
            samples: 64,
            steps: 60,
            seed: 233,
            lr0: 0.1,
            engine: EngineKind::Native,
            ..FinetuneConfig::default()
        })
        .unwrap();
    assert_eq!(report.engine, "native");
    assert!(report.final_loss.is_finite());
    assert!(report.val_accuracy >= 0.0 && report.val_accuracy <= 1.0);
    assert!(!report.loss_curve.is_empty());
    let curve: Vec<f32> = report.loss_curve.iter().map(|(_, l)| *l).collect();
    let n = curve.len().min(8);
    let head: f32 = curve[..n].iter().sum::<f32>() / n as f32;
    let tail: f32 = curve[curve.len() - n..].iter().sum::<f32>() / n as f32;
    assert!(
        tail < head,
        "native fine-tune must reduce loss: head {head} -> tail {tail} ({curve:?})"
    );
}

#[test]
fn factored_trajectory_tracks_dense_oracle_at_high_eps() {
    // At a near-lossless eps the factored model is numerically close to
    // the dense one, so short-horizon loss trajectories must track the
    // dense oracle (the shared reference both engines are tested
    // against).
    let cfg = DemoConfig { eps: 0.995, ..DemoConfig::default() };
    let dir = demo_dir("highEps", &cfg);
    let manifest = Manifest::load(&dir).unwrap();
    let mut curves = Vec::new();
    for model in ["vit_demo_vanilla", "vit_demo_wasi_eps100"] {
        let entry = manifest.model(model).unwrap();
        let mut eng = NativeModelEngine::load(entry).unwrap();
        let mut task = VisionTask::new("parity", entry.classes, 16, 0.5, 4, 233);
        let (x, y, _) = task.batch_onehot(entry.batch);
        let mut losses = Vec::new();
        for _ in 0..10 {
            losses.push(eng.step(&x, &y, 0.05).unwrap().loss);
        }
        curves.push(losses);
    }
    let (dense, wasi) = (&curves[0], &curves[1]);
    assert!(dense.last().unwrap() < dense.first().unwrap());
    assert!(wasi.last().unwrap() < wasi.first().unwrap());
    let mean_gap: f32 = dense
        .iter()
        .zip(wasi)
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>()
        / dense.len() as f32;
    assert!(
        mean_gap < 0.3,
        "factored trajectory diverged from dense oracle: gap {mean_gap}\n\
         dense {dense:?}\nwasi  {wasi:?}"
    );
}

#[test]
fn auto_selects_native_without_pjrt_and_hlo_errors() {
    let dir = demo_dir("auto", &DemoConfig::default());
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_demo_vanilla").unwrap();
    let rt = Runtime::native();

    // Demo variants ship no train HLO, so auto must route both
    // training and inference to the native engine in EVERY build
    // configuration.
    let auto = train_engine(&rt, entry, EngineKind::Auto).unwrap();
    assert_eq!(auto.backend(), "native");
    assert_eq!(auto.kind(), EngineKind::Native);
    let auto_infer = infer_engine(&rt, entry, EngineKind::Auto).unwrap();
    assert_eq!(auto_infer.backend(), "native");

    // Forcing the HLO train engine without a train artifact fails at
    // load with a clear message.
    let err = train_engine(&rt, entry, EngineKind::Hlo).unwrap_err();
    assert!(format!("{err:#}").contains("train artifact"), "{err:#}");

    // Forcing the HLO *infer* engine on a runtime that cannot execute
    // model HLO fails at run time with the documented pjrt pointer.
    let infer = infer_engine(&rt, entry, EngineKind::Hlo).unwrap();
    let params = entry.load_params().unwrap();
    let mut task = VisionTask::new("hloerr", entry.classes, 16, 0.5, 4, 1);
    let (x, _, _) = task.batch_onehot(entry.batch);
    let err = infer.infer(&params, &x).unwrap_err();
    assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
}

#[test]
fn trajectory_bit_identical_across_thread_counts() {
    // The kernel layer partitions output rows disjointly and each
    // element accumulates in ascending-k order, so the WHOLE training
    // trajectory — forward, backward, WSI refresh, ASI compression —
    // must not change a single bit between 1 and N threads.
    let dir = demo_dir("threads", &DemoConfig::default());
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_demo_wasi_eps80").unwrap();
    let mut task = VisionTask::new("thr", entry.classes, 16, 0.5, 4, 11);
    let (x, y, _) = task.batch_onehot(entry.batch);
    let run = |threads: usize| -> (Vec<f32>, Vec<f32>) {
        wasi_train::util::threadpool::set_num_threads(threads);
        let mut eng = NativeModelEngine::load(entry).unwrap();
        let losses = (0..6).map(|_| eng.step(&x, &y, 0.05).unwrap().loss).collect();
        let params = eng.params().to_vec();
        (losses, params)
    };
    let (losses1, params1) = run(1);
    let (losses4, params4) = run(4);
    wasi_train::util::threadpool::set_num_threads(0);
    assert_eq!(losses1, losses4, "losses diverged across thread counts");
    assert_eq!(params1, params4, "params diverged across thread counts");
}

#[test]
fn session_finetunes_with_explicit_thread_count() {
    // FinetuneConfig.threads plumbs through to the kernel layer; the
    // run must behave exactly like the default (engine + descent).
    let dir = demo_dir("threadcfg", &DemoConfig::default());
    let session = Session::open(dir.to_str().unwrap()).unwrap();
    let report = session
        .finetune(&FinetuneConfig {
            model: "vit_demo_wasi_eps80".into(),
            dataset: "cifar10-like".into(),
            samples: 32,
            steps: 20,
            seed: 233,
            lr0: 0.1,
            engine: EngineKind::Native,
            threads: Some(2),
            ..FinetuneConfig::default()
        })
        .unwrap();
    wasi_train::util::threadpool::set_num_threads(0);
    assert_eq!(report.engine, "native");
    assert!(report.final_loss.is_finite());
}

#[test]
fn checkpoint_roundtrip_is_bit_exact_across_engines() {
    let dir = demo_dir("ckpt", &DemoConfig::default());
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_demo_wasi_eps80").unwrap();

    let mut task = VisionTask::new("ckpt", entry.classes, 16, 0.5, 4, 7);
    let (x, y, _) = task.batch_onehot(entry.batch);

    let mut eng = NativeModelEngine::load(entry).unwrap();
    for _ in 0..3 {
        eng.step(&x, &y, 0.05).unwrap();
    }
    let ckpt = Checkpoint::from_engine(&eng, 3);
    let mut after_a = Vec::new();
    for _ in 0..2 {
        after_a.push(eng.step(&x, &y, 0.05).unwrap().loss);
    }

    let mut fresh = NativeModelEngine::load(entry).unwrap();
    ckpt.restore_into(&mut fresh).unwrap();
    assert_eq!(fresh.params(), ckpt.params.as_slice());
    let mut after_b = Vec::new();
    for _ in 0..2 {
        after_b.push(fresh.step(&x, &y, 0.05).unwrap().loss);
    }
    assert_eq!(after_a, after_b, "restored engine must replay identically");
}

#[test]
fn hlo_engine_parity_when_pjrt_available() {
    // The cross-engine trajectory check over the real artifacts: only a
    // live PJRT backend can execute model HLO, so this is a no-op (with
    // a notice) in the offline build — the contract is still exercised
    // above through the native engine.
    let rt = Runtime::cpu().unwrap();
    if !rt.can_execute_hlo() {
        eprintln!("engine_parity: no HLO-capable backend; skipping HLO side");
        return;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("engine_parity: artifacts not built; skipping");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("vit_vanilla").unwrap();
    let mut eng = train_engine(&rt, entry, EngineKind::Hlo).unwrap();
    let mut task = VisionTask::new("hlo", entry.classes, 32, 0.7, 8, 233);
    let mut losses = Vec::new();
    for _ in 0..8 {
        let (x, y, _) = task.batch_onehot(entry.batch);
        losses.push(eng.step(&x, &y, 0.05).unwrap().loss);
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(losses.last().unwrap() <= losses.first().unwrap());
}

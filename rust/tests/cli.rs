//! Integration tests for the `wasi-train` binary's artifact-free
//! surface: `cost-model`, `calibrate`, `list`, `plan-ranks`, and the
//! usage screen.  These run with default features and no artifacts
//! directory, so the whole CLI contract is exercised by plain
//! `cargo test` in offline CI.

use std::process::{Command, Output};

fn run(args: &[&str]) -> Output {
    // Run from a temp cwd so relative side-effect paths (eval_out/,
    // default artifacts/) never touch the repository checkout.
    Command::new(env!("CARGO_BIN_EXE_wasi-train"))
        .args(args)
        .current_dir(std::env::temp_dir())
        .output()
        .expect("spawn wasi-train binary")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn missing_artifacts_flagval() -> String {
    std::env::temp_dir()
        .join("wasi_cli_test_no_such_artifacts")
        .to_string_lossy()
        .into_owned()
}

#[test]
fn no_subcommand_prints_usage() {
    let out = run(&[]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("usage: wasi-train"), "{s}");
    for sub in ["train", "infer", "plan-ranks", "eval", "cost-model", "calibrate", "list"] {
        assert!(s.contains(sub), "usage must mention {sub}: {s}");
    }
}

#[test]
fn unknown_subcommand_prints_usage() {
    let out = run(&["frobnicate"]);
    assert!(out.status.success());
    assert!(stdout(&out).contains("usage: wasi-train"));
}

#[test]
fn cost_model_prints_fig2_sweep() {
    let out = run(&["cost-model"]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    for col in ["dim", "rank", "C_tr", "S_tr", "C_inf", "S_inf"] {
        assert!(s.contains(col), "missing column {col}: {s}");
    }
    // 4 dims x 3 ranks = 12 sweep rows + header + rule.
    assert!(s.lines().count() >= 14, "{s}");
    assert!(s.contains("2048"), "largest dim row missing: {s}");
}

#[test]
fn calibrate_reports_host_profile() {
    let out = run(&["calibrate"]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("GFLOP/s"), "{s}");
    assert!(s.contains("GB/s"), "{s}");
}

#[test]
fn list_without_artifacts_says_make_artifacts() {
    let out = run(&["list", "--artifacts", &missing_artifacts_flagval()]);
    assert!(!out.status.success(), "list must fail without artifacts");
    let err = stderr(&out);
    assert!(err.contains("manifest.json"), "{err}");
    assert!(err.contains("make artifacts"), "{err}");
}

#[test]
fn plan_ranks_without_artifacts_fails_with_context() {
    let out = run(&["plan-ranks", "--budget-kb", "64", "--artifacts", &missing_artifacts_flagval()]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("error:"), "{err}");
    assert!(err.contains("manifest.json"), "{err}");
}

#[test]
fn train_without_artifacts_fails_gracefully() {
    let out = run(&["train", "--steps", "1", "--artifacts", &missing_artifacts_flagval()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("error:"));
}

//! Integration tests for the `wasi-train` binary's artifact-free
//! surface: `cost-model`, `calibrate`, `list`, `plan-ranks`, and the
//! usage screen.  These run with default features and no artifacts
//! directory, so the whole CLI contract is exercised by plain
//! `cargo test` in offline CI.

use std::process::{Command, Output};

fn run(args: &[&str]) -> Output {
    // Run from a temp cwd so relative side-effect paths (eval_out/,
    // default artifacts/) never touch the repository checkout.
    Command::new(env!("CARGO_BIN_EXE_wasi-train"))
        .args(args)
        .current_dir(std::env::temp_dir())
        .output()
        .expect("spawn wasi-train binary")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn missing_artifacts_flagval() -> String {
    std::env::temp_dir()
        .join("wasi_cli_test_no_such_artifacts")
        .to_string_lossy()
        .into_owned()
}

#[test]
fn no_subcommand_prints_usage() {
    let out = run(&[]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("usage: wasi-train"), "{s}");
    let subs = [
        "train", "serve", "infer", "plan-ranks", "eval", "cost-model", "calibrate", "list", "demo",
    ];
    for sub in subs {
        assert!(s.contains(sub), "usage must mention {sub}: {s}");
    }
    for opt in ["--engine", "--lr", "--save-curve", "--silent", "infer:", "--workers", "submit"] {
        assert!(s.contains(opt), "usage must document {opt}: {s}");
    }
}

#[test]
fn unknown_subcommand_prints_usage() {
    let out = run(&["frobnicate"]);
    assert!(out.status.success());
    assert!(stdout(&out).contains("usage: wasi-train"));
}

#[test]
fn cost_model_prints_fig2_sweep() {
    let out = run(&["cost-model"]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    for col in ["dim", "rank", "C_tr", "S_tr", "C_inf", "S_inf"] {
        assert!(s.contains(col), "missing column {col}: {s}");
    }
    // 4 dims x 3 ranks = 12 sweep rows + header + rule.
    assert!(s.lines().count() >= 14, "{s}");
    assert!(s.contains("2048"), "largest dim row missing: {s}");
}

#[test]
fn calibrate_reports_host_profile() {
    let out = run(&["calibrate"]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("GFLOP/s"), "{s}");
    assert!(s.contains("GB/s"), "{s}");
}

#[test]
fn list_without_artifacts_says_make_artifacts() {
    let out = run(&["list", "--artifacts", &missing_artifacts_flagval()]);
    assert!(!out.status.success(), "list must fail without artifacts");
    let err = stderr(&out);
    assert!(err.contains("manifest.json"), "{err}");
    assert!(err.contains("make artifacts"), "{err}");
}

#[test]
fn plan_ranks_without_artifacts_fails_with_context() {
    let out = run(&[
        "plan-ranks", "--budget-kb", "64", "--artifacts", &missing_artifacts_flagval(),
    ]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("error:"), "{err}");
    assert!(err.contains("manifest.json"), "{err}");
}

#[test]
fn train_without_artifacts_fails_gracefully() {
    let out = run(&["train", "--steps", "1", "--artifacts", &missing_artifacts_flagval()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("error:"));
}

#[test]
fn train_rejects_unknown_engine() {
    let out = run(&["train", "--engine", "cuda", "--artifacts", &missing_artifacts_flagval()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown engine"), "{}", stderr(&out));
}

/// Satellite contract: a typo'd option must error with the accepted
/// set (before this PR `--step 50` silently trained the default 200
/// steps).
#[test]
fn subcommands_reject_unknown_options() {
    let out = run(&["train", "--step", "50", "--artifacts", &missing_artifacts_flagval()]);
    assert!(!out.status.success(), "--step must be rejected");
    let err = stderr(&out);
    assert!(err.contains("unknown option --step"), "{err}");
    assert!(err.contains("--steps"), "must list/suggest the real option: {err}");

    let out = run(&["bench", "--workers", "2"]);
    assert!(!out.status.success(), "bench takes no --workers");
    assert!(stderr(&out).contains("unknown option --workers"), "{}", stderr(&out));

    let out = run(&["eval", "--frobnicate", "--artifacts", &missing_artifacts_flagval()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown option --frobnicate"), "{}", stderr(&out));

    // The usage screen's common options are accepted everywhere —
    // `demo --threads N` must keep working (threads applies
    // process-wide before dispatch).
    let dir = std::env::temp_dir().join("wasi_cli_demo_threads");
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_string_lossy().into_owned();
    let out = run(&["demo", "--out", &dirs, "--threads", "2"]);
    assert!(out.status.success(), "common --threads rejected: {}", stderr(&out));
}

/// The PJRT-free acceptance path: `demo` generates artifacts in pure
/// rust, then `train --engine native` completes a full fine-tune with a
/// decreasing loss and a printed report — no Python, no HLO execution.
#[test]
fn demo_then_native_train_full_finetune() {
    let dir = std::env::temp_dir().join("wasi_cli_demo_e2e");
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_string_lossy().into_owned();
    let out = run(&["demo", "--out", &dirs]);
    assert!(out.status.success(), "demo failed: {}", stderr(&out));
    assert!(stdout(&out).contains("manifest.json"), "{}", stdout(&out));

    let curve = dir.join("curve.json").to_string_lossy().into_owned();
    let out = run(&[
        "train", "--artifacts", &dirs, "--engine", "native",
        "--model", "vit_demo_wasi_eps80", "--dataset", "cifar10-like",
        "--steps", "60", "--samples", "64", "--lr", "0.1", "--silent",
        "--save-curve", &curve,
    ]);
    assert!(out.status.success(), "train failed: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("engine native"), "{s}");
    assert!(s.contains("val accuracy"), "{s}");
    assert!(s.contains("final loss"), "{s}");

    // Loss must decrease across the saved curve.
    let json = std::fs::read_to_string(dir.join("curve.json")).unwrap();
    let losses: Vec<f32> = json
        .split("\"loss\":")
        .skip(1)
        .map(|chunk| {
            chunk
                .split(|c: char| c == ',' || c == '}')
                .next()
                .unwrap()
                .parse()
                .unwrap()
        })
        .collect();
    assert!(losses.len() >= 10, "{json}");
    let n = losses.len().min(8);
    let head: f32 = losses[..n].iter().sum::<f32>() / n as f32;
    let tail: f32 = losses[losses.len() - n..].iter().sum::<f32>() / n as f32;
    assert!(tail < head, "loss must fall under the native engine: {losses:?}");
}

/// `--precision` end to end on the CLI: a bf16 fine-tune trains and
/// reports its precision, int8 inference serves from the quantized
/// pool engine, and int8 training is refused with a helpful error.
#[test]
fn precision_flag_trains_bf16_and_serves_i8() {
    let dir = std::env::temp_dir().join("wasi_cli_precision");
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_string_lossy().into_owned();
    assert!(run(&["demo", "--out", &dirs]).status.success());

    let out = run(&[
        "train", "--artifacts", &dirs, "--engine", "native",
        "--model", "vit_demo_wasi_eps80", "--steps", "12", "--samples", "32",
        "--precision", "bf16", "--silent",
    ]);
    assert!(out.status.success(), "bf16 train failed: {}", stderr(&out));
    assert!(stdout(&out).contains("precision bf16"), "{}", stdout(&out));

    let out = run(&[
        "infer", "--artifacts", &dirs, "--model", "vit_demo_vanilla",
        "--precision", "i8",
    ]);
    assert!(out.status.success(), "i8 infer failed: {}", stderr(&out));
    assert!(stdout(&out).contains("i8 weights"), "{}", stdout(&out));

    let out = run(&[
        "train", "--artifacts", &dirs, "--engine", "native",
        "--model", "vit_demo_wasi_eps80", "--steps", "2", "--samples", "16",
        "--precision", "i8", "--silent",
    ]);
    assert!(!out.status.success(), "i8 training must be refused");
    assert!(stderr(&out).contains("inference-only"), "{}", stderr(&out));

    let out = run(&["train", "--artifacts", &dirs, "--precision", "f64"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown precision"), "{}", stderr(&out));
}

/// `bench --quick` must complete offline and emit a well-formed perf
/// record (the CI smoke step asserts the same file).
#[test]
fn bench_quick_emits_wellformed_perf_record() {
    let out_file = std::env::temp_dir().join("wasi_cli_bench.json");
    let _ = std::fs::remove_file(&out_file);
    let outs = out_file.to_string_lossy().into_owned();
    let out = run(&["bench", "--quick", "--steps", "3", "--out", &outs]);
    assert!(out.status.success(), "bench failed: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("wasi-train bench"), "{s}");
    assert!(s.contains("native"), "{s}");

    let json = std::fs::read_to_string(&out_file).unwrap();
    let v = wasi_train::util::json::Json::parse(&json).unwrap();
    assert_eq!(
        v.get("bench").and_then(|b| b.as_str()),
        Some("wasi-train bench")
    );
    let engines = v.get("engines").and_then(|e| e.as_arr()).unwrap();
    assert_eq!(engines.len(), 2, "{json}");
    let native = &engines[0];
    assert_eq!(native.get("engine").and_then(|e| e.as_str()), Some("native"));
    assert!(native.get("thread_speedup").and_then(|s| s.as_f64()).is_some());
    let arms = native.get("arms").and_then(|a| a.as_arr()).unwrap();
    assert!(!arms.is_empty());
    for arm in arms {
        assert!(arm.get("train_seconds").and_then(|x| x.as_f64()).unwrap() > 0.0);
    }
    // The HLO engine is recorded (available or not) rather than omitted.
    assert_eq!(engines[1].get("engine").and_then(|e| e.as_str()), Some("hlo"));
    assert!(v.get("nodes").and_then(|n| n.as_arr()).is_some());
    // SIMD-vs-scalar section: both arms plus the speedup ratios.
    let simd = v.get("simd").expect("simd section");
    assert!(simd.get("isa").and_then(|i| i.as_str()).is_some());
    for key in ["scalar", "simd"] {
        let arm = simd.get(key).expect(key);
        assert!(arm.get("train_seconds").and_then(|x| x.as_f64()).unwrap() > 0.0);
    }
    assert!(simd.get("train_speedup").and_then(|x| x.as_f64()).unwrap() > 0.0);
    // Precision section: f32/bf16/i8 arms with weight bytes strictly
    // shrinking, plus the int8-vs-f32 headline ratios.
    let prec = v.get("precision").expect("precision section");
    let parms = prec.get("arms").and_then(|a| a.as_arr()).unwrap();
    assert_eq!(parms.len(), 3, "{json}");
    let bytes: Vec<f64> = parms
        .iter()
        .map(|a| a.get("weight_bytes").and_then(|x| x.as_f64()).unwrap())
        .collect();
    assert!(bytes[0] > bytes[1] && bytes[1] > bytes[2], "{bytes:?}");
    for arm in parms {
        let agree = arm.get("top1_agreement").and_then(|x| x.as_f64()).unwrap();
        assert!((0.0..=1.0).contains(&agree), "{json}");
    }
    assert!(prec.get("int8_vs_f32_speedup").and_then(|x| x.as_f64()).unwrap() > 0.0);
    assert!(
        prec.get("int8_weight_compression").and_then(|x| x.as_f64()).unwrap() > 2.0,
        "{json}"
    );
    // The serve scheduler section: at least the 1-worker arm, with
    // throughput and latency percentiles recorded.
    let serve = v.get("serve").and_then(|s| s.as_arr()).expect("serve section");
    assert!(!serve.is_empty(), "{json}");
    for arm in serve {
        assert!(arm.get("workers").and_then(|x| x.as_usize()).unwrap() >= 1);
        assert!(arm.get("jobs_per_sec").and_then(|x| x.as_f64()).unwrap() > 0.0);
        let p50 = arm.get("p50_submit_to_done_s").and_then(|x| x.as_f64()).unwrap();
        let p95 = arm.get("p95_submit_to_done_s").and_then(|x| x.as_f64()).unwrap();
        assert!(p50 > 0.0 && p95 >= p50, "{json}");
    }
}

/// The acceptance-path smoke: `demo` then a scripted JSON-lines session
/// piped into `wasi-train serve` — a train-job submission interleaved
/// with an infer request must come back with a `Done` report.
#[test]
fn serve_accepts_piped_jsonlines_session() {
    use std::io::Write as _;

    let dir = std::env::temp_dir().join("wasi_cli_serve_smoke");
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_string_lossy().into_owned();
    assert!(run(&["demo", "--out", &dirs]).status.success());

    let script = [
        r#"{"cmd":"submit","model":"vit_demo_wasi_eps80","steps":4,"samples":32,"engine":"native"}"#,
        r#"{"cmd":"infer","model":"vit_demo_vanilla","seed":7}"#,
        r#"{"cmd":"events","job":1,"wait":true}"#,
        r#"{"cmd":"status","job":1}"#,
        r#"{"cmd":"shutdown"}"#,
    ]
    .join("\n");
    let mut child = Command::new(env!("CARGO_BIN_EXE_wasi-train"))
        .args(["serve", "--artifacts", &dirs, "--workers", "1"])
        .current_dir(std::env::temp_dir())
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn wasi-train serve");
    child
        .stdin
        .take()
        .unwrap()
        .write_all(script.as_bytes())
        .expect("pipe the scripted session");
    let out = child.wait_with_output().expect("serve must exit after shutdown");
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let s = stdout(&out);
    assert!(s.contains("\"cmd\":\"submit\"") && s.contains("\"job\":1"), "{s}");
    assert!(s.contains("\"event\":\"started\""), "{s}");
    assert!(s.contains("\"event\":\"done\""), "{s}");
    assert!(s.contains("\"state\":\"done\""), "{s}");
    assert!(s.contains("\"val_accuracy\""), "{s}");
    // The interleaved infer answered with predictions.
    assert!(s.contains("\"cmd\":\"infer\"") && s.contains("\"preds\""), "{s}");
    assert!(s.contains("\"cmd\":\"shutdown\""), "{s}");
    // Every stdout line is a JSON object.
    for line in s.lines() {
        assert!(
            line.starts_with('{') && line.ends_with('}'),
            "non-JSON response line: {line}"
        );
    }
}

/// `train --save-checkpoint` then `train --resume` through the CLI: the
/// resumed run continues to the same step count and reports a result.
#[test]
fn train_checkpoint_resume_cli_roundtrip() {
    let dir = std::env::temp_dir().join("wasi_cli_ckpt_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_string_lossy().into_owned();
    assert!(run(&["demo", "--out", &dirs]).status.success());
    let ckpt = dir.join("half.ckpt").to_string_lossy().into_owned();

    let out = run(&[
        "train", "--artifacts", &dirs, "--engine", "native",
        "--model", "vit_demo_wasi_eps80", "--steps", "6", "--samples", "32",
        "--silent", "--save-checkpoint", &ckpt,
    ]);
    assert!(out.status.success(), "train+checkpoint failed: {}", stderr(&out));
    assert!(std::path::Path::new(&ckpt).exists(), "checkpoint file missing");

    let out = run(&[
        "train", "--artifacts", &dirs, "--engine", "native",
        "--model", "vit_demo_wasi_eps80", "--steps", "12", "--samples", "32",
        "--silent", "--resume", &ckpt,
    ]);
    assert!(out.status.success(), "resume failed: {}", stderr(&out));
    assert!(stdout(&out).contains("val accuracy"), "{}", stdout(&out));
}

#[test]
fn infer_runs_without_train_artifact() {
    // Demo variants ship no train HLO at all, so they exercise exactly
    // the infer-only path: inference must work without ever touching a
    // train artifact (the params path no longer goes through
    // TrainStep::load).
    let dir = std::env::temp_dir().join("wasi_cli_infer_only");
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_string_lossy().into_owned();
    assert!(run(&["demo", "--out", &dirs]).status.success());
    let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    assert!(!manifest.contains("train_hlo"), "demo must be train-artifact-free");

    let out = run(&["list", "--artifacts", &dirs]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("infer-only"), "{}", stdout(&out));

    let out = run(&["infer", "--artifacts", &dirs, "--engine", "native",
                    "--model", "vit_demo_vanilla"]);
    assert!(out.status.success(), "infer-only inference failed: {}", stderr(&out));
    assert!(stdout(&out).contains("batch accuracy"), "{}", stdout(&out));
}

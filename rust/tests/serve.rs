//! Job-service integration tests (tier-1, artifact-free): the
//! multi-session serving core over the pure-rust demo artifacts.
//!
//! What is pinned:
//! * two CONCURRENT jobs on different variants produce loss curves
//!   bit-identical to running each job alone (the acceptance criterion:
//!   jobs share the pool but no mutable state, and the kernel layer is
//!   bit-deterministic across thread counts);
//! * checkpoint save → restore → resume through the Job API replays the
//!   uninterrupted trajectory bit-exactly (identical checkpoint bytes);
//! * `Session::finetune` and the service execute the same code path —
//!   their reports agree bit-for-bit for the same spec;
//! * the JSON-lines protocol drives a full submit/events/infer session
//!   over in-memory buffers.

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;

use wasi_train::coordinator::{FinetuneConfig, Session};
use wasi_train::engine::demo::{write_demo_artifacts, DemoConfig};
use wasi_train::engine::EngineKind;
use wasi_train::serve::{runner, JobSpec, PoolEntry, Service, ServiceConfig};

fn demo_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wasi_serve_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
    dir
}

fn cfg(model: &str, steps: usize, seed: u64) -> FinetuneConfig {
    FinetuneConfig::builder()
        .model(model)
        .samples(48)
        .steps(steps)
        .seed(seed)
        .lr0(0.1)
        .engine(EngineKind::Native)
        .build()
}

/// The acceptance criterion: two concurrent jobs on DIFFERENT variants
/// must yield loss curves bit-identical to running each alone.
#[test]
fn concurrent_jobs_match_sequential_bit_for_bit() {
    let dir = demo_dir("concurrent");
    let cfg_a = cfg("vit_demo_wasi_eps80", 12, 233);
    let cfg_b = cfg("vit_demo_vanilla", 12, 97);

    // Sequential baselines through the blocking Session front.
    let session = Session::open(dir.to_str().unwrap()).unwrap();
    let alone_a = session.finetune(&cfg_a).unwrap();
    let alone_b = session.finetune(&cfg_b).unwrap();

    // The same two specs, concurrently on a 2-worker service.
    let svc = Service::start(ServiceConfig::new(dir).with_workers(2)).unwrap();
    let id_a = svc.submit(JobSpec::new(cfg_a)).unwrap();
    let id_b = svc.submit(JobSpec::new(cfg_b)).unwrap();
    let conc_a = svc.wait(id_a).unwrap();
    let conc_b = svc.wait(id_b).unwrap();
    svc.shutdown();

    assert_eq!(
        alone_a.loss_curve, conc_a.loss_curve,
        "variant A's curve changed under concurrency"
    );
    assert_eq!(
        alone_b.loss_curve, conc_b.loss_curve,
        "variant B's curve changed under concurrency"
    );
    assert_eq!(alone_a.final_loss.to_bits(), conc_a.final_loss.to_bits());
    assert_eq!(alone_b.final_loss.to_bits(), conc_b.final_loss.to_bits());
    assert_eq!(alone_a.val_accuracy.to_bits(), conc_a.val_accuracy.to_bits());
    assert_eq!(alone_b.val_accuracy.to_bits(), conc_b.val_accuracy.to_bits());
}

/// Checkpoint save → restore → resume through the Job API: an
/// interrupted-and-resumed run must land on EXACTLY the bytes of the
/// uninterrupted one (params, state, and step all serialized).
#[test]
fn checkpoint_resume_through_job_api_is_bit_identical() {
    let dir = demo_dir("resume");
    let svc = Service::start(ServiceConfig::new(dir.clone()).with_workers(1)).unwrap();
    let full_ckpt = dir.join("full.ckpt");
    let half_ckpt = dir.join("half.ckpt");
    let resumed_ckpt = dir.join("resumed.ckpt");

    // Uninterrupted 10-step run.
    let mut spec = JobSpec::new(cfg("vit_demo_wasi_eps80", 10, 233));
    spec.checkpoint_to = Some(full_ckpt.clone());
    let full = svc.wait(svc.submit(spec).unwrap()).unwrap();

    // The same run cut at step 5...
    let mut spec = JobSpec::new(cfg("vit_demo_wasi_eps80", 5, 233));
    spec.checkpoint_to = Some(half_ckpt.clone());
    svc.wait(svc.submit(spec).unwrap()).unwrap();

    // ...and resumed to step 10.  Note: checkpoints store their step,
    // so the resumed spec asks for the full 10 steps.
    let mut spec = JobSpec::new(cfg("vit_demo_wasi_eps80", 10, 233));
    spec.resume_from = Some(half_ckpt.clone());
    spec.checkpoint_to = Some(resumed_ckpt.clone());
    let resumed = svc.wait(svc.submit(spec).unwrap()).unwrap();
    svc.shutdown();

    let full_bytes = std::fs::read(&full_ckpt).unwrap();
    let resumed_bytes = std::fs::read(&resumed_ckpt).unwrap();
    assert_eq!(
        full_bytes, resumed_bytes,
        "resumed checkpoint must be byte-identical to the uninterrupted run"
    );
    // Validation runs over the same loader/val split in both cases.
    assert_eq!(full.val_accuracy.to_bits(), resumed.val_accuracy.to_bits());
    // The resumed report's curve covers steps 5..10 only.
    assert!(resumed.loss_curve.iter().all(|(s, _)| *s >= 5), "{:?}", resumed.loss_curve);
    // And the overlapping tail matches the full run's curve bit-exactly.
    for (s, l) in &resumed.loss_curve {
        if let Some((_, lf)) = full.loss_curve.iter().find(|(fs, _)| fs == s) {
            assert_eq!(l.to_bits(), lf.to_bits(), "step {s} loss diverged on resume");
        }
    }
}

/// A resume whose checkpoint is already at/past the configured step
/// count is a client error, not a silent no-op.
#[test]
fn resume_past_configured_steps_errors() {
    let dir = demo_dir("resume_err");
    let svc = Service::start(ServiceConfig::new(dir.clone()).with_workers(1)).unwrap();
    let ckpt = dir.join("done.ckpt");
    let mut spec = JobSpec::new(cfg("vit_demo_vanilla", 5, 1));
    spec.checkpoint_to = Some(ckpt.clone());
    svc.wait(svc.submit(spec).unwrap()).unwrap();

    let mut spec = JobSpec::new(cfg("vit_demo_vanilla", 5, 1));
    spec.resume_from = Some(ckpt);
    let id = svc.submit(spec).unwrap();
    let err = svc.wait(id).unwrap_err();
    assert!(format!("{err:#}").contains("nothing to resume"), "{err:#}");
    svc.shutdown();
}

/// `Session::finetune` and a service worker run the SAME runner path:
/// identical specs must produce bit-identical reports.
#[test]
fn session_and_service_share_one_code_path() {
    let dir = demo_dir("onepath");
    let spec_cfg = cfg("vit_demo_wasi_eps80", 8, 233);

    let session = Session::open(dir.to_str().unwrap()).unwrap();
    let via_session = session.finetune(&spec_cfg).unwrap();

    // Reuse the session's pool entry for the direct runner call (what a
    // service worker executes), observing the event stream.
    let mut events = Vec::new();
    let never = AtomicBool::new(false);
    let outcome = runner::execute_job(
        session.pool_entry(),
        &JobSpec::new(spec_cfg.clone()),
        &mut |ev| events.push(format!("{ev:?}")),
        &never,
    )
    .unwrap();
    assert_eq!(via_session.loss_curve, outcome.report.loss_curve);
    assert_eq!(via_session.final_loss.to_bits(), outcome.report.final_loss.to_bits());
    assert_eq!(outcome.final_params.len(), {
        let entry: &wasi_train::runtime::ModelEntry =
            session.manifest().model("vit_demo_wasi_eps80").unwrap();
        entry.params_len
    });
    // Started + one event per step.
    assert_eq!(events.len(), 1 + spec_cfg.steps);
    assert!(events[0].contains("Started"), "{events:?}");

    // And a standalone PoolEntry (as `serve` would open) agrees too.
    let entry = PoolEntry::open(dir.to_str().unwrap()).unwrap();
    let outcome2 = runner::execute_job(
        &entry,
        &JobSpec::new(spec_cfg),
        &mut |_| {},
        &AtomicBool::new(false),
    )
    .unwrap();
    assert_eq!(via_session.loss_curve, outcome2.report.loss_curve);
}

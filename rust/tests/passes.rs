//! Pass-pipeline pins (tier-1, artifact-free): the optimization passes
//! (`engine/passes.rs` — frozen-subgraph folding, epilogue fusion,
//! arena-planned buffers, pre-packed weight panels) must be invisible
//! to the numerics.  Every pin here is BITWISE: the optimized executor
//! runs the same kernels in the same order on the same values as the
//! unoptimized one, so `--passes` is pure wall-clock/allocation — never
//! a results knob.
//!
//! What is pinned:
//! * training trajectories (per-step logits AND parameters) are
//!   bit-identical between `--passes all` and `--passes none`, on both
//!   the dense and the factored (WASI) demo variant, at f32 and under
//!   bf16 weight storage, and with each pass disabled individually;
//! * gradients out of the arena-planned backward are bit-identical to
//!   the unoptimized backward, and match finite differences;
//! * inference logits are bit-identical across every pass subset at
//!   f32, and across {panels, folding} on/off at bf16 and int8;
//! * the liveness checker refuses an arena layout with overlapping
//!   live ranges (the safety net under the planner's unsafe views);
//! * `PassSet` parsing/printing round-trips and `without` subsets work.
//!
//! Tests construct executors with explicit `new_with`/`new_infer_with`
//! and records with `pack_with` — never `set_passes` (process-global,
//! and the harness runs tests in parallel).

use std::path::PathBuf;

use wasi_train::data::synth::VisionTask;
use wasi_train::engine::demo::{write_demo_artifacts, DemoConfig};
use wasi_train::engine::passes::{assign_offsets, check_disjoint, ArenaLayout, Liveness, PassSet};
use wasi_train::engine::{GraphExecutor, LayerGraph, PackedParams};
use wasi_train::precision::{round_bf16_inplace, Precision};
use wasi_train::runtime::{Manifest, ModelEntry};

const VANILLA: &str = "vit_demo_vanilla";
const WASI: &str = "vit_demo_wasi_eps80";

fn demo_manifest(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("wasi_passes_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    (dir, manifest)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Drive `steps` full training steps exactly like
/// `NativeModelEngine::step` and return the bit pattern of every
/// per-step logit vector and parameter vector.
fn trajectory(entry: &ModelEntry, ps: PassSet, steps: usize, bf16: bool) -> Vec<u32> {
    let graph = LayerGraph::from_entry(entry).unwrap();
    let mut exec = GraphExecutor::new_with(graph, entry, ps).unwrap();
    let mut params = entry.load_params().unwrap();
    if bf16 {
        round_bf16_inplace(&mut params);
    }
    let mut grads = vec![0.0f32; params.len()];
    let side = entry.image_side().unwrap();
    let mut task = VisionTask::new("traj", entry.classes, side, 0.5, 4, 9);
    let mut out = Vec::new();
    for _ in 0..steps {
        let (x, y, _) = task.batch_onehot(entry.batch);
        let logits = exec.forward_train(&params, &x).unwrap();
        let (_, _, dlogits) = exec.loss_and_grad(&logits, &y);
        grads.fill(0.0);
        exec.backward(&params, &dlogits, &mut grads).unwrap();
        exec.update(&mut params, &grads, 0.05);
        if bf16 {
            round_bf16_inplace(&mut params);
        }
        out.extend(bits(&logits));
        out.extend(bits(&params));
    }
    out
}

#[test]
fn train_trajectory_bit_identical_across_passes() {
    let (_dir, m) = demo_manifest("traj");
    for model in [VANILLA, WASI] {
        let entry = m.model(model).unwrap();
        let want = trajectory(entry, PassSet::none(), 5, false);
        assert_eq!(
            trajectory(entry, PassSet::all(), 5, false),
            want,
            "{model}: optimized trajectory diverged from unoptimized"
        );
        for pass in ["fold", "fuse", "arena", "prepack"] {
            let ps = PassSet::all().without(pass).unwrap();
            assert_eq!(
                trajectory(entry, ps, 5, false),
                want,
                "{model}: trajectory diverged with {pass} disabled"
            );
        }
    }
}

#[test]
fn train_trajectory_bit_identical_under_bf16_storage() {
    let (_dir, m) = demo_manifest("trajbf16");
    let entry = m.model(WASI).unwrap();
    assert_eq!(
        trajectory(entry, PassSet::all(), 5, true),
        trajectory(entry, PassSet::none(), 5, true),
        "bf16-rounded trajectory diverged across passes"
    );
}

#[test]
fn gradients_bit_identical_and_match_finite_differences() {
    let (_dir, m) = demo_manifest("fd");
    let entry = m.model(VANILLA).unwrap();
    let params = entry.load_params().unwrap();
    let mut task = VisionTask::new("fd", entry.classes, 16, 0.5, 4, 3);
    let (x, y, _) = task.batch_onehot(entry.batch);

    let grads_with = |ps: PassSet| -> (GraphExecutor, Vec<f32>) {
        let graph = LayerGraph::from_entry(entry).unwrap();
        let mut exec = GraphExecutor::new_with(graph, entry, ps).unwrap();
        let logits = exec.forward_train(&params, &x).unwrap();
        let (_, _, dlogits) = exec.loss_and_grad(&logits, &y);
        let mut grads = vec![0.0f32; entry.params_len];
        exec.backward(&params, &dlogits, &mut grads).unwrap();
        (exec, grads)
    };
    let (mut exec, grads) = grads_with(PassSet::all());
    let (_, reference) = grads_with(PassSet::none());
    assert_eq!(
        bits(&grads),
        bits(&reference),
        "arena-planned backward diverged from the unoptimized backward"
    );

    // FD through the ARENA-PLANNED executor itself: loss_of re-enters
    // the planned forward, so the probe exercises the optimized path.
    let probes = [
        ("embed.w", 3usize),
        ("blocks.0.mlp.fc1.w", 7),
        ("blocks.1.attn.proj.w", 11),
        ("blocks.0.ln2.g", 2),
        ("cls", 5),
        ("pos", 13),
        ("head.w", 1),
    ];
    let h = 1e-2f32;
    let mut loss_of = |p: &[f32]| -> f32 {
        let logits = exec.forward_train(p, &x).unwrap();
        exec.loss_and_grad(&logits, &y).0
    };
    for (name, kidx) in probes {
        let spec = {
            let s = exec.plan().spec(name).unwrap();
            (s.offset, s.numel())
        };
        let idx = spec.0 + kidx.min(spec.1 - 1);
        let mut up = params.clone();
        up[idx] += h;
        let lp = loss_of(&up);
        let mut dn = params.clone();
        dn[idx] -= h;
        let lm = loss_of(&dn);
        let fd = (lp - lm) / (2.0 * h);
        let an = grads[idx];
        assert!(
            (fd - an).abs() < 2e-2 * fd.abs().max(1.0),
            "{name}[{kidx}]: fd {fd} vs analytic {an}"
        );
    }
}

#[test]
fn infer_logits_bit_identical_across_pass_subsets() {
    let (_dir, m) = demo_manifest("infer");
    for model in [VANILLA, WASI] {
        let entry = m.model(model).unwrap();
        let params = entry.load_params().unwrap();
        let side = entry.image_side().unwrap();
        let mut task = VisionTask::new("inf", entry.classes, side, 0.5, 4, 17);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let infer_with = |ps: PassSet| -> Vec<u32> {
            let graph = LayerGraph::from_entry(entry).unwrap();
            let exec = GraphExecutor::new_infer_with(graph, entry, ps).unwrap();
            bits(&exec.infer(&params, &x, entry.batch).unwrap())
        };
        let want = infer_with(PassSet::none());
        assert_eq!(infer_with(PassSet::all()), want, "{model}: all vs none");
        for pass in ["fold", "fuse", "arena", "prepack"] {
            let ps = PassSet::all().without(pass).unwrap();
            assert_eq!(infer_with(ps), want, "{model}: without {pass}");
        }
    }
}

#[test]
fn packed_infer_bit_identical_with_and_without_panels() {
    let (_dir, m) = demo_manifest("panels");
    for model in [VANILLA, WASI] {
        let entry = m.model(model).unwrap();
        let params = entry.load_params().unwrap();
        let side = entry.image_side().unwrap();
        let mut task = VisionTask::new("pan", entry.classes, side, 0.5, 4, 23);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let exec_all = GraphExecutor::new_infer_with(
            LayerGraph::from_entry(entry).unwrap(),
            entry,
            PassSet::all(),
        )
        .unwrap();
        let exec_none = GraphExecutor::new_infer_with(
            LayerGraph::from_entry(entry).unwrap(),
            entry,
            PassSet::none(),
        )
        .unwrap();
        for prec in [Precision::Bf16, Precision::I8] {
            let on = PackedParams::pack_with(entry, &params, prec, PassSet::all()).unwrap();
            let off = PackedParams::pack_with(entry, &params, prec, PassSet::none()).unwrap();
            assert!(on.panel_count() > 0, "{model}@{prec}: no panels packed");
            assert_eq!(off.panel_count(), 0, "{model}@{prec}: panels despite none");
            let want = bits(&exec_none.infer_packed(&off, &x, entry.batch).unwrap());
            for (tag, exec, packed) in [
                ("planned+panels", &exec_all, &on),
                ("planned+repack", &exec_all, &off),
                ("unplanned+panels", &exec_none, &on),
            ] {
                assert_eq!(
                    bits(&exec.infer_packed(packed, &x, entry.batch).unwrap()),
                    want,
                    "{model}@{prec}: {tag} diverged from unplanned+repack"
                );
            }
        }
    }
}

#[test]
fn liveness_rejects_overlapping_arena_layout() {
    let mut lv = Liveness::new();
    let a = lv.alloc(0, 64);
    lv.touch(a, 3);
    let b = lv.alloc(1, 32);
    lv.touch(b, 2);
    let c = lv.alloc(4, 16); // born after `a` and `b` die: may share
    lv.touch(c, 5);
    assert_eq!(lv.sum_elems(), 112);

    let layout = assign_offsets(lv.intervals());
    check_disjoint(lv.intervals(), &layout).unwrap();
    assert!(layout.total >= 96, "a and b are simultaneously live");
    assert!(layout.total < 112, "c must reuse freed space");

    // Hand-corrupt the layout so `a` and `b` collide: the checker that
    // guards the executors' unsafe arena views must refuse it.
    let bad = ArenaLayout { offsets: vec![0, 0, layout.total], total: layout.total + 16 };
    let err = check_disjoint(lv.intervals(), &bad).unwrap_err().to_string();
    assert!(err.contains("overlap"), "unexpected error: {err}");
}

#[test]
fn passset_parse_display_round_trips() {
    assert_eq!(PassSet::parse("all").unwrap(), PassSet::all());
    assert_eq!(PassSet::parse("none").unwrap(), PassSet::none());
    let ps = PassSet::parse("arena,prepack").unwrap();
    assert!(ps.arena() && ps.prepack() && !ps.fold() && !ps.fuse());
    assert_eq!(PassSet::parse(&ps.to_string()).unwrap(), ps);
    assert_eq!(PassSet::all().to_string(), "all");
    assert_eq!(PassSet::none().to_string(), "none");
    let sub = PassSet::all().without("arena").unwrap();
    assert!(!sub.arena() && sub.fold() && sub.fuse() && sub.prepack());
    assert!(PassSet::parse("turbo").is_err());
}

//! Variant-store integration tests (tier-1, artifact-free): per-user
//! subspace deltas over the shared frozen base (DESIGN.md §Variant
//! store), over the pure-rust demo artifacts.
//!
//! What is pinned:
//! * serving a finished job from its delta record matches serving it
//!   from the retained full parameter vector at EVERY serving precision
//!   (f32 zero-copy overlay, bf16/i8 transient materialize-then-pack);
//! * a bf16-trained job's record reproduces the job's exact final
//!   params (frozen region = bf16-rounded base) and refuses the
//!   raw-base overlay;
//! * the f32 overlay path produces logits bit-identical to inference
//!   over the materialized vector;
//! * paging is exactly-once: a one-record budget forces an eviction per
//!   install, a `get` of the evicted key reloads from disk exactly
//!   once, and predictions are bit-identical across the round trip;
//! * an unknown on-disk format version is refused with an actionable
//!   error (and `gc` drops exactly that record);
//! * extraction refuses a job whose frozen region drifted from the
//!   shared base, and refuses variants with no subspace at all.

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;

use wasi_train::coordinator::FinetuneConfig;
use wasi_train::data::synth::VisionTask;
use wasi_train::engine::demo::{write_demo_artifacts, DemoConfig};
use wasi_train::engine::{EngineKind, InferEngine, NativeInferEngine};
use wasi_train::precision::Precision;
use wasi_train::serve::{runner, InferParams, InferRequest, JobSpec, PoolEntry};
use wasi_train::store::{extract_delta, DeltaRecord, VariantStore, DELTA_VERSION};

const MODEL: &str = "vit_demo_wasi_eps80";

fn demo_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wasi_store_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
    dir
}

/// Run one delta-persisted job to completion and return its extracted
/// record alongside the full final params the retained-full path would
/// have kept.
fn delta_job(pool: &PoolEntry, precision: Precision, seed: u64) -> (DeltaRecord, Vec<f32>) {
    let cfg = FinetuneConfig::builder()
        .model(MODEL)
        .samples(48)
        .steps(6)
        .seed(seed)
        .lr0(0.1)
        .engine(EngineKind::Native)
        .precision(precision)
        .build();
    let mut spec = JobSpec::new(cfg);
    spec.persist_delta = true;
    let out = runner::execute_job(pool, &spec, &mut |_| {}, &AtomicBool::new(false)).unwrap();
    (out.delta.expect("a persist_delta job must yield a record"), out.final_params)
}

fn infer_req(precision: Precision) -> InferRequest {
    InferRequest {
        model: MODEL.to_string(),
        engine: EngineKind::Auto,
        precision,
        seed: 7,
        x: None,
    }
}

fn bitwise(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// The core parity pin: the delta-apply serving path must agree with
/// the retained-full path at every serving precision.
#[test]
fn delta_apply_matches_retained_full_across_serving_precisions() {
    let dir = demo_dir("parity");
    let pool = PoolEntry::open(&dir).unwrap();
    let (rec, full_params) = delta_job(&pool, Precision::F32, 233);
    assert_eq!(rec.train_precision, Precision::F32);
    // The record is the point: a small fraction of the full vector.
    assert!(
        rec.elems() * 4 < full_params.len(),
        "delta holds {} of {} params — not a small subspace",
        rec.elems(),
        full_params.len()
    );
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        let req = infer_req(precision);
        let full = runner::run_infer_with(&pool, &req, InferParams::Full(&full_params)).unwrap();
        let delta = runner::run_infer_with(&pool, &req, InferParams::Delta(&rec)).unwrap();
        assert_eq!(
            full.preds,
            delta.preds,
            "{precision}: delta-apply diverged from retained-full"
        );
        assert_eq!(full.correct, delta.correct, "{precision}: accuracy diverged");
    }
}

/// A bf16-trained job's frozen region is the bf16-rounded base:
/// `apply()` must rebuild the job's exact final params bit for bit, and
/// the raw-base overlay (which cannot represent the rounding) must be
/// refused.
#[test]
fn bf16_trained_delta_reproduces_the_jobs_exact_params() {
    let dir = demo_dir("bf16");
    let pool = PoolEntry::open(&dir).unwrap();
    let (rec, full_params) = delta_job(&pool, Precision::Bf16, 97);
    assert_eq!(rec.train_precision, Precision::Bf16);
    let base = pool.initial_params(MODEL).unwrap();
    let err = rec.overlay(&base).err().expect("bf16 overlay over the raw base must be refused");
    assert!(format!("{err:#}").contains("apply()"), "{err:#}");
    let applied = rec.apply(&base).unwrap();
    assert_eq!(
        bitwise(&applied),
        bitwise(&full_params),
        "apply() must reproduce the finished job's params exactly"
    );
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        let req = infer_req(precision);
        let full = runner::run_infer_with(&pool, &req, InferParams::Full(&full_params)).unwrap();
        let delta = runner::run_infer_with(&pool, &req, InferParams::Delta(&rec)).unwrap();
        assert_eq!(full.preds, delta.preds, "{precision}: bf16 delta path diverged");
    }
}

/// The zero-copy overlay serves logits bit-identical to inference over
/// the materialized personalized vector — delta-apply is not an
/// approximation of full personalization at any bit.
#[test]
fn overlay_logits_are_bitwise_identical_to_materialized() {
    let dir = demo_dir("overlay");
    let pool = PoolEntry::open(&dir).unwrap();
    let (rec, full_params) = delta_job(&pool, Precision::F32, 233);
    let entry = pool.manifest.model(MODEL).unwrap();
    let base = pool.initial_params(MODEL).unwrap();
    let applied = rec.apply(&base).unwrap();
    assert_eq!(bitwise(&applied), bitwise(&full_params));
    let engine = NativeInferEngine::load(entry).unwrap();
    let side = entry.image_side().unwrap();
    let mut task = VisionTask::new("ov", entry.classes, side, 0.7, 8, 3);
    let (x, _, _) = task.batch_onehot(entry.batch);
    let want = bitwise(&engine.infer(&applied, &x).unwrap());
    let overlay = rec.overlay(&base).unwrap();
    let got = bitwise(&engine.infer_overlay(&overlay, &x).unwrap());
    assert_eq!(want, got, "overlay logits must be bit-identical to the full vector");
}

/// Exactly-once paging under a one-record budget: installs evict, a
/// `get` of the evicted key reloads from disk exactly once, and the
/// served predictions are bit-identical across the round trip.
#[test]
fn evict_reload_round_trip_is_exactly_once_and_bit_identical() {
    let dir = demo_dir("page");
    let pool = PoolEntry::open(&dir).unwrap();
    let (rec_a, _) = delta_job(&pool, Precision::F32, 11);
    let (rec_b, _) = delta_job(&pool, Precision::F32, 22);
    let req = infer_req(Precision::F32);
    let want = runner::run_infer_with(&pool, &req, InferParams::Delta(&rec_a)).unwrap();

    let store = VariantStore::open(&dir.join("store"), rec_a.bytes()).unwrap();
    store.put("user-a", rec_a).unwrap();
    store.put("user-b", rec_b).unwrap();
    assert!(!store.is_resident("user-a"), "one-record budget must page user-a out");
    assert!(store.is_resident("user-b"));

    let reloaded = store.get("user-a").unwrap();
    let after = runner::run_infer_with(&pool, &req, InferParams::Delta(&reloaded)).unwrap();
    assert_eq!(want.preds, after.preds, "predictions changed across evict→reload");

    let s = store.stats().unwrap();
    assert_eq!(s.puts, 2);
    assert_eq!(s.misses, 1);
    assert_eq!(s.reloads, 1, "a miss reloads exactly once");
    assert_eq!(s.evictions, 2, "user-a paged out by user-b's put, user-b by the reload");
    assert_eq!(s.resident, 1);
    assert_eq!(s.disk_records, 2, "eviction never deletes the on-disk record");

    // A second get is a pure hit: no extra disk load.
    store.get("user-a").unwrap();
    let s = store.stats().unwrap();
    assert_eq!(s.hits, 1);
    assert_eq!(s.reloads, 1, "a resident key must not reload");
}

/// A record from a future (or corrupted-to-unknown) format version is
/// refused with an actionable error, never misread — and `gc` drops
/// exactly that record.
#[test]
fn unknown_format_version_is_refused_and_gc_drops_it() {
    let dir = demo_dir("version");
    let pool = PoolEntry::open(&dir).unwrap();
    let (rec, _) = delta_job(&pool, Precision::F32, 5);
    let mut bytes = rec.encode();
    let round = DeltaRecord::decode(&bytes).unwrap();
    assert_eq!(round.model, rec.model);
    assert_eq!(round.base_hash, rec.base_hash);

    bytes[4] = (DELTA_VERSION + 1) as u8;
    let err = DeltaRecord::decode(&bytes).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("format version"), "{text}");
    assert!(text.contains("store gc"), "the error must point at the remedy: {text}");

    let store = VariantStore::open(&dir.join("store"), 0).unwrap();
    std::fs::write(store.dir().join("future.delta"), &bytes).unwrap();
    assert!(store.get("future").is_err(), "an unreadable record must not serve");
    assert_eq!(store.gc().unwrap(), vec!["future".to_string()]);
    assert!(store.list().unwrap().is_empty());
}

/// Extraction is refusal-first: a job whose frozen region drifted from
/// the shared base is rejected (persisting it as a delta would be
/// lossy), as is a variant with no subspace at all.
#[test]
fn extraction_refuses_drifted_or_unfactored_jobs() {
    let dir = demo_dir("drift");
    let pool = PoolEntry::open(&dir).unwrap();
    let entry = pool.manifest.model(MODEL).unwrap();
    let base = entry.load_params().unwrap();
    let mut trained = base.clone();
    // Flat offset 0 is the patch-embed weight — never part of a
    // subspace factor, so this simulates full (non-restricted) training.
    trained[0] += 1.0;
    let err = extract_delta(entry, &base, &trained, Precision::F32).unwrap_err();
    assert!(format!("{err:#}").contains("frozen"), "{err:#}");

    let vanilla = pool.manifest.model("vit_demo_vanilla").unwrap();
    let vbase = vanilla.load_params().unwrap();
    let err = extract_delta(vanilla, &vbase, &vbase, Precision::F32).unwrap_err();
    assert!(format!("{err:#}").contains("no factored"), "{err:#}");
}

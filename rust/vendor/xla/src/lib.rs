//! Offline compile-time stub of the `xla` (PJRT) crate.
//!
//! Declares exactly the API surface `wasi-train`'s PJRT client uses —
//! `PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`, `Literal`,
//! `HloModuleProto`, `XlaComputation` — so `cargo check --features pjrt`
//! type-checks the whole PJRT code path without network access or a
//! libxla toolchain.  Every entry point returns
//! [`Error::StubUnavailable`] at runtime; to actually execute HLO
//! artifacts, replace the `xla` path dependency in `rust/Cargo.toml`
//! with the real crates.io `xla` crate (see the repository README).

use std::path::Path;

const STUB_MSG: &str =
    "stub xla crate: swap in the real `xla` crate (rust/Cargo.toml) for PJRT execution";

/// Stub error: everything maps to `StubUnavailable`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Error {
    /// The vendored stub cannot execute anything.
    StubUnavailable(&'static str),
}

/// Stub result alias matching the real crate's shape.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::StubUnavailable(STUB_MSG))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// Real crate: create a CPU PJRT client.  Stub: always errors, so
    /// callers fall back to the native runtime.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Platform name of the underlying PJRT device.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; returns per-device output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device-resident output buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host tensor literal (stub).
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    /// Read the literal out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_errors_not_panics() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}

//! Vendored, dependency-free drop-in for the subset of the `anyhow` crate
//! this workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Exists so the default build needs **zero network access** (the target
//! environments — edge CI, air-gapped boards — cannot reach crates.io).
//! The API is call-compatible with real `anyhow` for everything the
//! `wasi-train` crate does, so swapping back to the crates.io version is
//! a one-line change in `rust/Cargo.toml`.
//!
//! Semantics mirrored from upstream:
//! * `Display` prints the outermost message; `{:#}` (alternate) prints
//!   the whole cause chain separated by `: `.
//! * `Debug` prints the message plus a `Caused by:` list (what
//!   `unwrap()` / `main() -> Result<()>` show).
//! * A blanket `From<E: std::error::Error>` lets `?` lift any standard
//!   error; `Error` itself deliberately does NOT implement
//!   `std::error::Error` (same coherence trick as upstream).

use std::fmt;

/// Error type: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the same defaulted form as upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message (what `anyhow!` calls).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message (without the cause chain).
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our own, innermost first.
        let mut msgs = vec![e.to_string()];
        let mut cur = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut built: Option<Box<Error>> = None;
        for msg in msgs.into_iter().rev() {
            built = Some(Box::new(Error { msg, source: built }));
        }
        *built.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad value {} at {}", 7, "site");
        assert_eq!(e.to_string(), "bad value 7 at site");
    }

    #[test]
    fn question_mark_lifts_std_errors() {
        fn parse() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u8, std::io::Error> = Ok(1);
        let v = ok
            .with_context(|| -> String { panic!("must not evaluate on Ok") })
            .unwrap();
        assert_eq!(v, 1);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).is_err());
        assert!(f(11).unwrap_err().to_string().contains("too big"));
    }
}

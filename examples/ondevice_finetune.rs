//! End-to-end on-device fine-tuning driver (the repository's E2E
//! validation run, recorded in EXPERIMENTS.md).
//!
//! Fine-tunes BOTH the vanilla and the WASI ε=0.8 ViT artifacts for a few
//! hundred steps on the synthetic CIFAR-10-like task, logging the loss
//! curves, final validation accuracy, per-step wallclock, and the memory
//! breakdown — i.e. the full paper pipeline (pretrained model → on-device
//! fine-tune in the subspace) through all three layers.
//!
//!     cargo run --release --example ondevice_finetune [steps]

use anyhow::Result;
use wasi_train::coordinator::{FinetuneConfig, Session};
use wasi_train::engine::EngineKind;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let artifacts = std::env::var("WASI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine: EngineKind = std::env::var("WASI_ENGINE")
        .unwrap_or_else(|_| "auto".into())
        .parse()?;
    let session = Session::open(&artifacts)?;

    let mut summary = Vec::new();
    for model in ["vit_vanilla", "vit_wasi_eps80"] {
        println!("\n=== fine-tuning {model} for {steps} steps (cifar10-like, seed 233) ===");
        let report = session.finetune(&FinetuneConfig {
            model: model.into(),
            dataset: "cifar10-like".into(),
            samples: 512,
            steps,
            seed: 233,
            verbose: true,
            engine,
            ..FinetuneConfig::default()
        })?;
        println!("engine: {}", report.engine);
        println!("\nloss curve ({model}):");
        for (s, l) in &report.loss_curve {
            println!("  step {s:>4}  loss {l:.4}");
        }
        println!(
            "{model}: val acc {:.3}, mean step {:.1} ms, train mem {:.2} MB",
            report.val_accuracy,
            report.mean_step_seconds * 1e3,
            report.memory.total_mb()
        );
        summary.push((model, report));
    }

    let (van, wasi) = (&summary[0].1, &summary[1].1);
    println!("\n=== E2E comparison (vanilla vs WASI eps=0.8) ===");
    println!(
        "accuracy : vanilla {:.3}  wasi {:.3}  (gap {:+.3})",
        van.val_accuracy,
        wasi.val_accuracy,
        wasi.val_accuracy - van.val_accuracy
    );
    println!(
        "step time: vanilla {:.1} ms  wasi {:.1} ms  (speedup {:.2}x)",
        van.mean_step_seconds * 1e3,
        wasi.mean_step_seconds * 1e3,
        van.mean_step_seconds / wasi.mean_step_seconds
    );
    println!(
        "train mem: vanilla {:.2} MB  wasi {:.2} MB  (compression {:.1}x)",
        van.memory.total_mb(),
        wasi.memory.total_mb(),
        van.memory.total_mb() / wasi.memory.total_mb()
    );
    Ok(())
}

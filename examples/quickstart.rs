//! Quickstart: load the WASI ViT artifact, fine-tune for a handful of
//! steps on a synthetic CIFAR-like task, and report loss + memory.
//!
//! Run after `make artifacts build`:
//!     cargo run --release --example quickstart

use anyhow::Result;
use wasi_train::coordinator::{FinetuneConfig, Session};
use wasi_train::engine::EngineKind;

fn main() -> Result<()> {
    let artifacts = std::env::var("WASI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    // WASI_ENGINE=auto|hlo|native (auto falls back to the native
    // full-model engine when the runtime cannot execute model HLO).
    let engine: EngineKind = std::env::var("WASI_ENGINE")
        .unwrap_or_else(|_| "auto".into())
        .parse()?;
    println!("opening session over {artifacts}/ ...");
    let session = Session::open(&artifacts)?;
    println!("platform: {}", session.runtime().platform());
    println!("models:   {:?}", session.manifest().models.keys().collect::<Vec<_>>());

    // The builder is the stable embedding API (unset knobs keep the
    // paper defaults).
    let cfg = FinetuneConfig::builder()
        .model("vit_wasi_eps80")
        .dataset("cifar10-like")
        .samples(256)
        .steps(30)
        .seed(233)
        .verbose(true)
        .engine(engine)
        .build();
    println!("\nfine-tuning {} on {} for {} steps ...", cfg.model, cfg.dataset, cfg.steps);
    let report = session.finetune(&cfg)?;

    println!("\n=== quickstart report ===");
    println!("engine                : {}", report.engine);
    println!("final (smoothed) loss : {:.4}", report.final_loss);
    println!("validation accuracy   : {:.3}", report.val_accuracy);
    println!("mean step time        : {:.1} ms", report.mean_step_seconds * 1e3);
    println!(
        "training memory       : {:.2} MB ({} weight elems, {} act elems, {} state elems)",
        report.memory.total_mb(),
        report.memory.weights,
        report.memory.activations,
        report.memory.asi_state
    );
    Ok(())
}

//! Multi-session serving example: the deployment shape the on-device
//! personalization literature targets — a long-lived service running
//! concurrent fine-tuning jobs while answering inference requests from
//! the same shared model pool.
//!
//! Uses the pure-rust demo artifacts so it runs offline:
//!     cargo run --release --example personalize_service

use anyhow::Result;
use wasi_train::coordinator::FinetuneConfig;
use wasi_train::engine::demo::{write_demo_artifacts, DemoConfig};
use wasi_train::engine::EngineKind;
use wasi_train::serve::{InferRequest, JobEvent, JobSpec, Service, ServiceConfig};

fn main() -> Result<()> {
    let dir = std::env::temp_dir().join("wasi_personalize_service_demo");
    let _ = std::fs::remove_dir_all(&dir);
    write_demo_artifacts(&dir, &DemoConfig::default())?;
    println!("demo artifacts -> {}", dir.display());

    // A service with two workers: two personalization jobs train
    // concurrently on different variants.
    let service = Service::start(ServiceConfig::new(dir).with_workers(2))?;
    let mut jobs = Vec::new();
    for (user, model) in [("alice", "vit_demo_wasi_eps80"), ("bob", "vit_demo_vanilla")] {
        let cfg = FinetuneConfig::builder()
            .model(model)
            .samples(64)
            .steps(40)
            .lr0(0.1)
            .engine(EngineKind::Native)
            .build();
        let id = service.submit(JobSpec::new(cfg))?;
        println!("submitted job {id} ({user} -> {model})");
        jobs.push((user, model, id));
    }

    // Inference interleaves with the running jobs (pretrained params).
    let probe = InferRequest {
        model: "vit_demo_vanilla".into(),
        engine: EngineKind::Auto,
        precision: wasi_train::precision::Precision::F32,
        seed: 233,
        x: None,
    };
    let out = service.infer(None, &probe, None)?;
    println!(
        "inference during training: {}/{} correct (pretrained params)",
        out.correct.unwrap_or(0),
        out.batch
    );

    // Stream one job's progress; wait for both.
    let (user0, _, id0) = jobs[0];
    if let Some(events) = service.take_events(id0) {
        for ev in events {
            if let JobEvent::Step { record, .. } = ev {
                if record.step % 10 == 0 {
                    println!("[{user0}] step {:>3} loss {:.4}", record.step, record.loss);
                }
            }
        }
    }
    for (user, model, id) in &jobs {
        let report = service.wait(*id)?;
        println!(
            "{user}: {model} fine-tuned, final loss {:.4}, val acc {:.3}",
            report.final_loss,
            report.val_accuracy
        );
        // Personalized inference against the finished job's weights.
        let personalized = service.infer(
            None,
            &InferRequest {
                model: (*model).into(),
                engine: EngineKind::Auto,
                precision: wasi_train::precision::Precision::F32,
                seed: 233,
                x: None,
            },
            Some(*id),
        )?;
        println!(
            "{user}: personalized inference {}/{} correct",
            personalized.correct.unwrap_or(0),
            personalized.batch
        );
    }
    service.shutdown();
    Ok(())
}

//! Rank-selection planner demo (paper App. A.2, Eqs. 29-32).
//!
//! Sweeps activation-memory budgets and shows how the DP planner trades
//! perplexity for memory per layer — the deployment-planning workflow an
//! on-device integrator would run before shipping a fine-tune config.
//!
//!     cargo run --release --example rank_planner

use anyhow::Result;
use wasi_train::runtime::Manifest;
use wasi_train::util::table::Table;
use wasi_train::wasi::rank_select::{plan_ranks, plan_ranks_wasi};

fn main() -> Result<()> {
    let artifacts = std::env::var("WASI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let manifest = Manifest::load(&artifacts)?;
    let table = manifest
        .perplexity
        .as_ref()
        .expect("manifest has no perplexity table — run `make artifacts`");

    println!(
        "perplexity table: {} layers x {} thresholds\n",
        table.layers.len(),
        table.eps_grid.len()
    );

    // Budgeted planning (Eq. 30) across a budget sweep.
    let mut t = Table::new(["budget (KB)", "total mem (KB)", "total perplexity", "per-layer eps"])
        .title("Budgeted DP planner (Eq. 30)");
    for kb in [16usize, 32, 48, 64, 96, 128, 256] {
        match plan_ranks(table, kb * 256, 4096) {
            Ok(plan) => {
                let eps: Vec<String> = plan
                    .choice
                    .iter()
                    .map(|&j| format!("{}", table.eps_grid[j]))
                    .collect();
                t.row([
                    kb.to_string(),
                    format!("{:.1}", plan.total_memory as f64 / 256.0),
                    format!("{:.2}", plan.total_perplexity),
                    eps.join(","),
                ]);
            }
            Err(e) => {
                t.row([kb.to_string(), "-".into(), format!("infeasible: {e}"), String::new()]);
            }
        }
    }
    t.print();

    // Budget-free WASI planning (Eq. 32) at each uniform threshold.
    let mut t2 = Table::new(["eps", "total mem (KB)", "total perplexity"])
        .title("\nUniform-threshold WASI planner (Eq. 32)");
    for &eps in &table.eps_grid {
        let plan = plan_ranks_wasi(table, eps)?;
        t2.row([
            format!("{eps}"),
            format!("{:.1}", plan.total_memory as f64 / 256.0),
            format!("{:.2}", plan.total_perplexity),
        ]);
    }
    t2.print();
    println!("\nhigher budgets buy lower total perplexity (gradient fidelity);");
    println!("the DP picks non-uniform per-layer thresholds the uniform sweep cannot.");
    Ok(())
}

//! Edge-device latency/energy explorer.
//!
//! Measures real per-iteration train/infer wallclock of the ViT variants
//! on this host (through the compiled HLO executables), calibrates the
//! host's sustained GFLOP/s, and projects to the paper's four boards —
//! the workflow behind Fig. 8 and Tabs. 2-4.
//!
//!     cargo run --release --example edge_latency

use anyhow::Result;
use wasi_train::device::calibrate::measure_gflops;
use wasi_train::device::energy::iteration_energy;
use wasi_train::device::latency::project_time;
use wasi_train::device::spec::DEVICES;
use wasi_train::eval::latency::measure_iteration;
use wasi_train::eval::EvalCtx;
use wasi_train::util::table::Table;

fn main() -> Result<()> {
    let artifacts = std::env::var("WASI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let ctx = EvalCtx::open(&artifacts, "eval_out", 60, true)?;

    println!("calibrating host ...");
    let hg = measure_gflops(192, 2);
    println!("host sustained matmul: {hg:.1} GFLOP/s\n");

    let mut t = Table::new(["variant", "host infer (ms)", "host train (ms)"])
        .title("Measured per-iteration time (host, PJRT CPU)");
    let mut measured = Vec::new();
    for name in ["vit_wasi_eps40", "vit_wasi_eps80", "vit_vanilla"] {
        let Ok(entry) = ctx.session.manifest().model(name) else { continue };
        let entry = entry.clone();
        let (inf, tr) = measure_iteration(&ctx, &entry, 3)?;
        t.row([name.to_string(), format!("{:.0}", inf * 1e3), format!("{:.0}", tr * 1e3)]);
        measured.push((name, inf, tr));
    }
    t.print();

    let mut t2 = Table::new(["variant", "device", "infer (s)", "train (s)", "train energy (J)"])
        .title("\nProjected to edge devices (roofline, AI=64)");
    for (name, inf, tr) in &measured {
        for dev in DEVICES {
            let pi = project_time(*inf, hg, dev, 64.0);
            let pt = project_time(*tr, hg, dev, 64.0);
            t2.row([
                name.to_string(),
                dev.name.to_string(),
                format!("{pi:.2}"),
                format!("{pt:.2}"),
                format!("{:.1}", iteration_energy(dev, pt)),
            ]);
        }
    }
    t2.print();
    Ok(())
}
